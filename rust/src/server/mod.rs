//! TCP serving front-end + clients, speaking the [`crate::proto`]
//! envelope over two codecs on one port.
//!
//! Dispatch is one function — [`ServerCore::handle`] maps a typed
//! [`Request`] to a [`Response`] by routing it into the
//! [`ModelRegistry`] — and the wire format is a pluggable codec in
//! front of it (DESIGN.md §2.2–2.3):
//!
//! * **framed binary** (`proto::frame`): length-prefixed frames,
//!   HELLO/ACK version negotiation (v2 and v3), request ids. A client
//!   may pipeline many REQUEST frames before reading responses and may
//!   pack many volleys into one frame; responses come back in order,
//!   ids echoed. v3 adds per-request model routing and the registry
//!   admin ops.
//! * **text compat** (`proto::text`): the legacy newline protocol
//!   (`INFER`/`LEARN`/`SPARSE`/`SLEARN`/`STATS`/`PING`/`QUIT`),
//!   byte-for-byte compatible with pre-v2 clients, plus an optional
//!   `@model` prefix token for routing.
//!
//! The server sniffs the first four bytes of each connection: the frame
//! magic `CWK2` selects the framed codec, anything else is treated as
//! the first text verb. One thread per connection; batching happens in
//! each model slot's [`crate::coordinator::DynamicBatcher`], so
//! concurrent clients of one model (and the volleys of one multi-volley
//! frame) coalesce into full backend batches without diluting another
//! model's batches.
//!
//! ```text
//! -> INFER 1,3,16,16,0,...        (n comma-separated spike times)
//! <- OK winner=2 times=4,16,2,...
//! -> @edge SPARSE 0:1,4:3         (route to model `edge`)
//! <- OK winner=2 spikes=0:4,2:2   (columns that fired, column:time)
//! -> STATS
//! <- sorted key=value lines, blank-line terminated
//! -> QUIT
//! <- BYE
//! ```

use crate::coordinator::{BatcherConfig, TnnHandle};
use crate::error::{Error, Result};
use crate::proto::{
    frame, text, AdminReply, ModelCmd, ModelInfo, Op, Outcome, Request, Response, StatsSnapshot,
};
use crate::registry::{ModelRegistry, RegistryConfig};
use crate::volley::{self, SpikeVolley, VolleyResult};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The codec-independent dispatch core: every wire protocol funnels
/// into [`ServerCore::handle`], which routes into the model registry.
pub struct ServerCore {
    registry: Arc<ModelRegistry>,
    /// The default model's `(n, c, t_max)`, cached for the ACK — a
    /// geometry tuple rather than a handle, because a column-sharded
    /// default model has no single full-geometry engine to hand out.
    default_geometry: (usize, usize, usize),
}

impl ServerCore {
    /// Single-model compat constructor: wraps `service` as the default
    /// (and only initial) model of a fresh registry. Models created
    /// over the wire open against the same artifact directory the
    /// wrapped handle was opened with.
    pub fn new(service: TnnHandle, cfg: BatcherConfig) -> ServerCore {
        let registry = ModelRegistry::with_default(
            "default",
            service.clone(),
            RegistryConfig {
                artifacts_dir: service.artifacts_dir.clone(),
                batcher: cfg,
                ..RegistryConfig::default()
            },
        );
        ServerCore::with_registry(Arc::new(registry))
    }

    /// The multi-model constructor: dispatch into an existing registry.
    /// A standby shard host's registry ([`ModelRegistry::standby`])
    /// boots with no default model — nothing exists until a
    /// coordinator provisions it over the wire — so the ACK then
    /// advertises a zero geometry instead of refusing to serve.
    pub fn with_registry(registry: Arc<ModelRegistry>) -> ServerCore {
        let default_geometry = match registry.slot(None) {
            Ok(slot) => (slot.n(), slot.c(), slot.t_max()),
            Err(_) => (0, 0, 0),
        };
        ServerCore {
            registry,
            default_geometry,
        }
    }

    /// The default model's `(n, c, t_max)` (the ACK geometry).
    pub fn default_geometry(&self) -> (usize, usize, usize) {
        self.default_geometry
    }

    /// The registry this core dispatches into.
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Handle one envelope request (by value — the volleys move
    /// straight into the batcher queue, no hot-path clone). `received`
    /// is when the request came off the wire; the deadline opt is
    /// measured against it twice — here at dispatch (cheap early-out),
    /// and again by the batcher when the batch is drained, so the
    /// budget bounds the queue wait too, not just decode time.
    ///
    /// Routing: `opts.model` selects the registry slot (`None` = the
    /// default model); an unknown name is a typed error outcome. The
    /// slot lookup is a read-lock + `Arc` clone, so the infer/learn hot
    /// path never contends with admin ops beyond that.
    pub fn handle(&self, req: Request, received: Instant) -> Response {
        self.handle_traced(req, received, None)
    }

    /// [`handle`](ServerCore::handle) with the codec's decode timing
    /// attached (the request's `Decode` span when it is sampled).
    ///
    /// This is also where a request's trace context is born: a
    /// propagated id (`FLAG_TRACE`, set by a coordinator on its shard
    /// RPCs) is adopted so the spans recorded here stitch to the
    /// originating request; otherwise [`crate::obs::begin_request`]
    /// allocates a fresh id and takes the head-sampling decision. The
    /// ctx rides a thread-local for the duration of dispatch — the QoS
    /// gate, batcher and shard layers pick it up from there — and the
    /// request's summary span (with error/BUSY/expired flags) is
    /// recorded on the way out. None of this touches the `Response`,
    /// which is what keeps reply bytes bit-identical under tracing.
    pub fn handle_traced(
        &self,
        req: Request,
        received: Instant,
        decode: Option<Duration>,
    ) -> Response {
        let ctx = match req.opts.trace {
            Some(id) => crate::obs::adopt(id),
            None => crate::obs::begin_request(),
        };
        let _ctx_guard = crate::obs::set_current(ctx);
        if let Some(dur) = decode {
            crate::obs::record(ctx, crate::obs::Stage::Decode, 0, received, dur);
        }
        let resp = self.dispatch(req, received);
        let mut flags = 0u8;
        match &resp.outcome {
            Outcome::Error(e) => {
                flags |= crate::obs::SPAN_ERROR;
                if e.contains("deadline") {
                    flags |= crate::obs::SPAN_EXPIRED;
                }
            }
            Outcome::Busy { .. } => flags |= crate::obs::SPAN_BUSY,
            _ => {}
        }
        crate::obs::finish_request(ctx, received, flags);
        resp
    }

    fn dispatch(&self, req: Request, received: Instant) -> Response {
        let deadline = req.opts.deadline_ms.map(|ms| received + Duration::from_millis(ms as u64));
        // >=, so a 0 ms budget is deterministically expired
        if deadline.is_some_and(|d| Instant::now() >= d) {
            // dispatch-level expiry is still *accounted* expiry: the
            // request dies right here (it never reaches a batcher
            // drain), so this is its exactly-once increment — and the
            // submit-side counters are mirrored so the invariant
            // `requests >= requests_expired` holds on this path too
            self.count_dispatch_expiry(&req);
            return Response::error(
                req.id,
                format!(
                    "deadline exceeded: waited {:?} against a {} ms budget",
                    received.elapsed(),
                    req.opts.deadline_ms.unwrap_or(0)
                ),
            );
        }
        let outcome = match req.op {
            Op::Infer | Op::Learn => {
                let learn = req.op == Op::Learn;
                match self.registry.slot(req.opts.model.as_deref()) {
                    // admission runs before any queue slot or compute
                    // is spent; the permit spans the batched run so the
                    // lane's in-flight count tracks real load
                    Ok(slot) => match slot.admit(learn, req.volleys.len()) {
                        // a gated LEARN (the distributed two-phase
                        // protocol's phase 2) bypasses the batcher and
                        // applies the caller-supplied global gates
                        Ok(_permit) => match req.gates {
                            Some(gates) if learn => slot.run_gated(req.volleys, gates, deadline),
                            Some(_) => {
                                Outcome::Error("gates ride only on LEARN requests".into())
                            }
                            None => slot.run_batched(learn, req.volleys, deadline),
                        },
                        Err(Error::Busy { retry_after_ms }) => Outcome::Busy { retry_after_ms },
                        Err(e) => Outcome::Error(e.to_string()),
                    },
                    Err(e) => Outcome::Error(e.to_string()),
                }
            }
            Op::Stats => {
                match self
                    .registry
                    .stats(!req.opts.counters_only, req.opts.model.as_deref())
                {
                    Ok(s) => Outcome::Stats(s),
                    Err(e) => Outcome::Error(e.to_string()),
                }
            }
            Op::Ping => Outcome::Pong,
            Op::Quit => Outcome::Bye,
            Op::Admin(cmd) => self.registry.admin(cmd),
        };
        Response {
            id: req.id,
            outcome,
        }
    }

    /// Metrics for a request expiring at the dispatch check (the
    /// silent-expiry gap fixed in PR 7): before this, a request dying
    /// here left no trace in any counter, while drain-level expiry
    /// counted — so `requests_expired` undercounted exactly the
    /// requests that were most late. Mirrors the batcher's submit-side
    /// accounting (volley-granular `requests`/`requests_sparse`/
    /// `requests_dense`), then counts the expiry itself. Exactly once
    /// per request: dispatch expiry returns before anything is
    /// enqueued, so the drain path can never see (or recount) it.
    fn count_dispatch_expiry(&self, req: &Request) {
        if !matches!(req.op, Op::Infer | Op::Learn) || req.volleys.is_empty() {
            return;
        }
        let Ok(slot) = self.registry.slot(req.opts.model.as_deref()) else {
            return;
        };
        let m = slot.metrics();
        let sparse = req.volleys.iter().filter(|v| v.is_sparse()).count() as u64;
        let total = req.volleys.len() as u64;
        m.incr("requests", total);
        if sparse > 0 {
            m.incr("requests_sparse", sparse);
        }
        if total > sparse {
            m.incr("requests_dense", total - sparse);
        }
        m.incr("requests_expired", total);
    }
}

/// Serving daemon state.
pub struct Server {
    core: Arc<ServerCore>,
    stop: Arc<AtomicBool>,
    /// Global concurrent-connection cap (`--max-conns`); `None` =
    /// unlimited (the pre-cap behavior).
    max_conns: Option<usize>,
    /// Live connection count, shared with every connection's
    /// [`ConnGuard`].
    live: Arc<AtomicUsize>,
}

impl Server {
    pub fn new(service: TnnHandle, cfg: BatcherConfig) -> Server {
        Server {
            core: Arc::new(ServerCore::new(service, cfg)),
            stop: Arc::new(AtomicBool::new(false)),
            max_conns: None,
            live: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// A server dispatching into an existing multi-model registry.
    pub fn with_registry(registry: Arc<ModelRegistry>) -> Server {
        Server {
            core: Arc::new(ServerCore::with_registry(registry)),
            stop: Arc::new(AtomicBool::new(false)),
            max_conns: None,
            live: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Cap concurrent connections (`repro serve --max-conns N`):
    /// connection N+1 is answered with the codec-appropriate BUSY
    /// shape — the same first-class refusal the QoS gate sheds with —
    /// and closed, instead of spawning an unbounded handler thread.
    /// `0` means unlimited.
    pub fn with_max_conns(mut self, n: usize) -> Server {
        self.max_conns = (n > 0).then_some(n);
        self
    }

    /// Handle for shutting the accept loop down from another thread.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// The dispatch core (for in-process callers: benches, tests).
    pub fn core(&self) -> Arc<ServerCore> {
        self.core.clone()
    }

    /// Bind and serve until the stop flag is set. Returns the bound port
    /// through `on_bound` (port 0 = ephemeral). The accept loop doubles
    /// as the registry's autosave clock ([`ModelRegistry::autosave_due`]
    /// checked every tick, the sweep itself on a worker thread), and a
    /// final save runs at shutdown for any checkpoint-enabled registry
    /// so a clean stop never loses learned state.
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(u16)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?.port());
        let registry = self.core.registry().clone();
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut fatal: Option<Error> = None;
        while !self.stop.load(Ordering::Acquire) {
            // sweep finished connection/autosave threads so a daemon
            // serving for weeks never accumulates dead JoinHandles
            workers.retain(|w| !w.is_finished());
            // the accept loop is only the autosave *clock*; the sweep
            // itself (engine round-trips + fsyncs per model) runs on a
            // worker thread so connecting clients never wait on it
            if registry.autosave_due() {
                let registry = registry.clone();
                workers.push(std::thread::spawn(move || {
                    if let Err(e) = registry.save_all() {
                        eprintln!("autosave: {e}");
                    }
                }));
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    // connection cap: over-cap connections get a typed
                    // BUSY refusal on whichever codec they speak —
                    // never a silent close, never an unbounded spawn
                    if self
                        .max_conns
                        .is_some_and(|cap| self.live.load(Ordering::Acquire) >= cap)
                    {
                        registry.metrics.incr("connections_refused", 1);
                        let retry_ms = registry.retry_hint_ms();
                        workers.push(std::thread::spawn(move || {
                            let _ = refuse_conn(stream, retry_ms);
                        }));
                        continue;
                    }
                    let guard = ConnGuard::enter(self.live.clone());
                    let core = self.core.clone();
                    let stop = self.stop.clone();
                    workers.push(std::thread::spawn(move || {
                        let _guard = guard;
                        let _ = handle_conn(stream, core, stop);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                // a hard accept error ends the loop but must still flow
                // through the shutdown path below — learned state is
                // flushed even when the listener dies (e.g. EMFILE)
                Err(e) => {
                    fatal = Some(e.into());
                    break;
                }
            }
        }
        for w in workers {
            let _ = w.join();
        }
        // shutdown flush: checkpoint-enabled registries persist on stop
        if let Err(e) = registry.final_autosave() {
            eprintln!("final autosave: {e}");
        }
        match fatal {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// RAII live-connection count: incremented at accept, decremented when
/// the connection thread exits however it exits (clean BYE, EOF, codec
/// error, panic unwind) — the connection cap can never leak slots.
struct ConnGuard(Arc<AtomicUsize>);

impl ConnGuard {
    fn enter(live: Arc<AtomicUsize>) -> ConnGuard {
        live.fetch_add(1, Ordering::AcqRel);
        ConnGuard(live)
    }
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Answer an over-cap connection with the codec-appropriate BUSY
/// shape, then close. Short socket timeouts bound the sniff — a
/// slow-loris connect cannot pin refusal threads while the cap is hit.
fn refuse_conn(stream: TcpStream, retry_ms: u32) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut head = [0u8; 4];
    match read_head(&mut reader, &mut head)? {
        0 => Ok(()),
        4 if head == frame::MAGIC => {
            // consume the HELLO so the client's first read is this
            // refusal, not a reset mid-handshake; the reply rides the
            // degraded (error-form) BUSY because no version was
            // negotiated — every client version can decode it, and
            // FramedClient::connect surfaces it as the typed
            // handshake rejection
            let _ = frame::read_frame_after_magic(&mut reader)?;
            send_response(&mut out, &Response::busy(0, retry_ms).degrade_busy())
        }
        _ => {
            // text: the same first-class BUSY line the QoS shed uses
            out.write_all(format!("BUSY {retry_ms}\n").as_bytes())?;
            out.flush()?;
            Ok(())
        }
    }
}

/// Sniff the codec from the first four bytes, then run the matching
/// connection loop.
fn handle_conn(stream: TcpStream, core: Arc<ServerCore>, stop: Arc<AtomicBool>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let out = stream;
    let mut head = [0u8; 4];
    match read_head(&mut reader, &mut head)? {
        0 => return Ok(()), // client connected and left
        4 if head == frame::MAGIC => serve_framed(reader, out, core, stop),
        k => serve_text(reader, out, core, stop, &head[..k]),
    }
}

/// Read the first bytes of a connection for codec sniffing — at most 4,
/// one at a time, stopping the moment the prefix can no longer be the
/// frame magic. The early bail matters for interactive text clients: a
/// short first line (`"X\n"` + wait) must get its `ERR` reply instead
/// of deadlocking against a sniffer waiting for byte 4. (No text verb
/// starts with `C`, the magic's first byte, so real text lines bail
/// after one read.)
fn read_head(r: &mut impl Read, head: &mut [u8; 4]) -> Result<usize> {
    let mut off = 0;
    while off < 4 {
        match r.read(&mut head[off..off + 1]) {
            Ok(0) => break,
            Ok(k) => {
                off += k;
                if head[..off] != frame::MAGIC[..off] {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(off)
}

/// The v2 framed loop: HELLO/ACK handshake, then request frames until
/// `Quit`, EOF or the stop flag. The first frame's magic was consumed
/// by the sniffer.
fn serve_framed(
    mut reader: BufReader<TcpStream>,
    mut out: TcpStream,
    core: Arc<ServerCore>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let (ty, payload) = frame::read_frame_after_magic(&mut reader)?;
    if ty != frame::FrameType::Hello {
        send_response(&mut out, &Response::error(0, "expected HELLO frame"))?;
        return Ok(());
    }
    let (min, max) = match frame::decode_hello(&payload) {
        Ok(range) => range,
        Err(e) => {
            send_response(&mut out, &Response::error(0, e.to_string()))?;
            return Ok(());
        }
    };
    let Some(version) = frame::negotiate(min, max) else {
        send_response(
            &mut out,
            &Response::error(
                0,
                format!(
                    "no common protocol version: client speaks {min}..{max}, server speaks {}",
                    frame::VERSION
                ),
            ),
        )?;
        return Ok(());
    };
    let (n, c, t_max) = core.default_geometry();
    frame::write_frame(
        &mut out,
        frame::FrameType::Ack,
        &frame::encode_ack(&frame::Ack {
            version,
            n: n as u32,
            c: c as u32,
            t_max: t_max as u32,
        }),
    )?;
    out.flush()?;

    loop {
        let Some((ty, payload)) = frame::read_frame(&mut reader)? else {
            return Ok(()); // clean close
        };
        let received = Instant::now();
        if stop.load(Ordering::Acquire) {
            return Ok(());
        }
        let resp = if ty != frame::FrameType::Request {
            Response::error(0, format!("unexpected frame type {ty:?}"))
        } else {
            match frame::decode_request(&payload) {
                // a malformed payload inside an intact frame is
                // recoverable — answer and keep the connection
                Err(e) => Response::error(0, e.to_string()),
                // the negotiated version is a contract, not advice: a
                // v2 connection must not reach the v3 surface (and must
                // never be answered with a v3-only status byte)
                Ok(req)
                    if version < 3
                        && (req.opts.model.is_some()
                            || req.gates.is_some()
                            || req.opts.trace.is_some()
                            || matches!(req.op, Op::Admin(_))) =>
                {
                    Response::error(
                        req.id,
                        "model routing, admin ops, learn gates and trace ids need \
                         protocol v3 (this connection negotiated v2)",
                    )
                }
                Ok(req) => {
                    // decode cost is only measured when the tracer is
                    // live — the disabled hot path takes zero clock reads
                    let decode = crate::obs::enabled().then(|| received.elapsed());
                    core.handle_traced(req, received, decode)
                }
            }
        };
        // the negotiated version caps the *reply* surface too: a QoS
        // shed on a v2 connection degrades from the status-6 BUSY
        // frame to the generic error form, so a v2 peer never sees a
        // status byte it cannot decode
        let resp = if version < 3 { resp.degrade_busy() } else { resp };
        let bye = matches!(resp.outcome, Outcome::Bye);
        send_response(&mut out, &resp)?;
        if bye {
            return Ok(());
        }
    }
}

fn send_response(out: &mut TcpStream, resp: &Response) -> Result<()> {
    frame::write_frame(out, frame::FrameType::Response, &frame::encode_response(resp)?)?;
    out.flush()?;
    Ok(())
}

/// The text compat loop. `head` holds the sniffed first bytes of the
/// first line.
///
/// Model routing happens **before** parsing: the optional `@model`
/// prefix names the registry slot whose geometry `(n, t_max)` the rest
/// of the line is validated against — different models legitimately
/// take different volley widths. Unrouted lines use the default
/// model's geometry, exactly the pre-registry behavior.
fn serve_text(
    mut reader: BufReader<TcpStream>,
    mut out: TcpStream,
    core: Arc<ServerCore>,
    stop: Arc<AtomicBool>,
    head: &[u8],
) -> Result<()> {
    let mut prefix = String::from_utf8_lossy(head).into_owned();
    let mut line = String::new();
    loop {
        line.clear();
        line.push_str(&prefix);
        prefix.clear();
        // the sniffed head may already contain (part of) the first line
        if !line.contains('\n') && reader.read_line(&mut line)? == 0 && line.is_empty() {
            return Ok(()); // client closed
        }
        if let Some(pos) = line.find('\n') {
            prefix.push_str(&line[pos + 1..]);
            line.truncate(pos);
        }
        let received = Instant::now();
        let line = line.trim();
        if stop.load(Ordering::Acquire) {
            return Ok(());
        }
        let reply = match text_request(&core, line) {
            Ok((req, t_max)) => {
                let sparse_reply = req.opts.sparse_reply;
                let decode = crate::obs::enabled().then(|| received.elapsed());
                let resp = core.handle_traced(req, received, decode);
                let rendered = text::render_response(&resp, sparse_reply, t_max);
                if matches!(resp.outcome, Outcome::Bye) {
                    out.write_all(rendered.as_bytes())?;
                    out.flush()?;
                    return Ok(());
                }
                rendered
            }
            Err(e) => format!("ERR {e}\n"),
        };
        out.write_all(reply.as_bytes())?;
        out.flush()?;
    }
}

/// Resolve a text line to an envelope request plus the `t_max` its
/// reply renders against (the routed model's, for sparse replies).
fn text_request(core: &ServerCore, line: &str) -> Result<(Request, usize)> {
    let (model, rest) = text::split_model(line)?;
    let slot = core.registry().slot(model)?;
    let mut req = text::parse_line(rest, slot.n(), slot.t_max())?;
    if let Some(m) = model {
        req.opts.model = Some(m.to_string());
    }
    Ok((req, slot.t_max()))
}

/// Pipelining window shared by both clients: at most this many requests
/// in flight per socket flush. The server answers serially while a
/// client writes, so an unbounded pipeline could fill both socket
/// buffers and deadlock writer-against-writer.
const PIPELINE_WINDOW: usize = 64;
/// Byte bound on one pipelined window — the count bound alone would not
/// stop 64 huge multi-volley frames from filling the buffers anyway.
/// 64 KiB outgoing keeps the (smaller) serial responses comfortably
/// inside default socket buffers.
const PIPELINE_WINDOW_BYTES: usize = 64 << 10;

/// Socket timeouts for both clients — a hung server must not wedge a
/// caller forever.
#[derive(Clone, Copy, Debug)]
pub struct ClientConfig {
    pub connect_timeout: Duration,
    /// `None` = block forever (opt out explicitly).
    pub read_timeout: Option<Duration>,
    pub write_timeout: Option<Duration>,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_secs(10),
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(30)),
        }
    }
}

fn connect_stream(addr: &str, cfg: &ClientConfig) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, cfg.connect_timeout) {
            Ok(stream) => {
                stream.set_read_timeout(cfg.read_timeout)?;
                stream.set_write_timeout(cfg.write_timeout)?;
                return Ok(stream);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(last
        .map(Error::Io)
        .unwrap_or_else(|| Error::Server(format!("`{addr}` resolved to no addresses"))))
}

/// Blocking text-protocol client (the compat surface; the load
/// generator and every pre-v2 test use it). For the v2 binary protocol
/// see [`FramedClient`].
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect with [`ClientConfig::default`] timeouts.
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with(addr, &ClientConfig::default())
    }

    pub fn connect_with(addr: &str, cfg: &ClientConfig) -> Result<Client> {
        let stream = connect_stream(addr, cfg)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    fn roundtrip(&mut self, line: &str) -> Result<String> {
        writeln!(self.writer, "{line}")?;
        self.writer.flush()?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        Ok(reply.trim().to_string())
    }

    /// Envelope entry point over the text codec. `Infer`/`Learn`
    /// requests carry dense volleys (the text wire has no handshake to
    /// learn `t_max` from, so sparse volleys cannot be densified here —
    /// use [`FramedClient`] or the `*_sparse` wrappers); multi-volley
    /// requests pipeline one line per volley; a model opt becomes the
    /// `@model` prefix token on every line. Options the text wire
    /// cannot express are a typed error, never silently dropped — the
    /// same `Request` must not mean different things on the two
    /// clients.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        if req.opts.deadline_ms.is_some() {
            return Err(Error::Proto(
                "the text codec cannot carry a deadline; use FramedClient".into(),
            ));
        }
        if req.opts.counters_only {
            return Err(Error::Proto(
                "the text codec cannot request counters-only stats; use FramedClient".into(),
            ));
        }
        if req.opts.sparse_reply {
            return Err(Error::Proto(
                "text call speaks the dense wire form; use infer_sparse/learn_sparse \
                 or FramedClient"
                    .into(),
            ));
        }
        // the `@model` routing prefix, applied to every line we emit
        let at = match &req.opts.model {
            Some(m) => format!("@{m} "),
            None => String::new(),
        };
        let outcome = match &req.op {
            Op::Admin(_) => {
                return Err(Error::Proto(
                    "the text codec has no admin verbs; use FramedClient".into(),
                ))
            }
            Op::Infer | Op::Learn => {
                let verb = if req.op == Op::Infer { "INFER" } else { "LEARN" };
                let mut payloads = Vec::with_capacity(req.volleys.len());
                for v in &req.volleys {
                    let SpikeVolley::Dense(times) = v else {
                        return Err(Error::Proto(
                            "text call carries dense volleys only; use FramedClient \
                             or infer_sparse/learn_sparse"
                                .into(),
                        ));
                    };
                    let fields: Vec<String> = times.iter().map(|t| format!("{t}")).collect();
                    payloads.push(format!("{at}{verb} {}\n", fields.join(",")));
                }
                // pipeline lines in bounded windows (count and bytes),
                // collecting each window's replies before the next —
                // never enough unread data in flight to deadlock
                let mut results = Vec::with_capacity(payloads.len());
                let mut first_err: Option<String> = None;
                let mut i = 0;
                while i < payloads.len() {
                    let mut lines = String::new();
                    let mut count = 0;
                    while i < payloads.len()
                        && count < PIPELINE_WINDOW
                        && lines.len() < PIPELINE_WINDOW_BYTES
                    {
                        lines.push_str(&payloads[i]);
                        i += 1;
                        count += 1;
                    }
                    self.writer.write_all(lines.as_bytes())?;
                    self.writer.flush()?;
                    for _ in 0..count {
                        let mut reply = String::new();
                        self.reader.read_line(&mut reply)?;
                        match parse_ok(reply.trim()) {
                            Ok((winner, times)) => results.push(VolleyResult {
                                times,
                                winner: if winner < 0 {
                                    None
                                } else {
                                    Some(winner as usize)
                                },
                            }),
                            Err(e) => {
                                first_err.get_or_insert(e.to_string());
                            }
                        }
                    }
                }
                match first_err {
                    Some(e) => Outcome::Error(e),
                    None => Outcome::Results(results),
                }
            }
            Op::Stats => {
                writeln!(self.writer, "{at}STATS")?;
                self.writer.flush()?;
                Outcome::Stats(self.read_stats()?)
            }
            Op::Ping => {
                let reply = self.roundtrip(&format!("{at}PING"))?;
                if reply != "PONG" {
                    return Err(Error::Server(format!("server said: {reply}")));
                }
                Outcome::Pong
            }
            Op::Quit => {
                let _ = self.roundtrip(&format!("{at}QUIT"))?;
                Outcome::Bye
            }
        };
        Ok(Response {
            id: req.id,
            outcome,
        })
    }

    pub fn infer(&mut self, volley: &[f32]) -> Result<(i64, Vec<f32>)> {
        let payload: Vec<String> = volley.iter().map(|t| format!("{t}")).collect();
        let reply = self.roundtrip(&format!("INFER {}", payload.join(",")))?;
        parse_ok(&reply)
    }

    pub fn learn(&mut self, volley: &[f32]) -> Result<(i64, Vec<f32>)> {
        let payload: Vec<String> = volley.iter().map(|t| format!("{t}")).collect();
        let reply = self.roundtrip(&format!("LEARN {}", payload.join(",")))?;
        parse_ok(&reply)
    }

    /// Sparse-encoded inference: send only the spiking `(line, time)`
    /// pairs, receive the `(column, time)` pairs of the columns that
    /// fired.
    pub fn infer_sparse(&mut self, spikes: &[(usize, f32)]) -> Result<(i64, Vec<(usize, f32)>)> {
        let reply = self.roundtrip(&format!("SPARSE {}", volley::encode_pairs(spikes)))?;
        parse_ok_sparse(&reply)
    }

    /// Sparse-encoded learning step (`SLEARN`).
    pub fn learn_sparse(&mut self, spikes: &[(usize, f32)]) -> Result<(i64, Vec<(usize, f32)>)> {
        let reply = self.roundtrip(&format!("SLEARN {}", volley::encode_pairs(spikes)))?;
        parse_ok_sparse(&reply)
    }

    /// Typed server metrics (the versioned `key=value` STATS schema).
    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        writeln!(self.writer, "STATS")?;
        self.writer.flush()?;
        self.read_stats()
    }

    fn read_stats(&mut self) -> Result<StatsSnapshot> {
        let mut block = String::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 || line.trim().is_empty() {
                break; // blank line terminates the block
            }
            block.push_str(&line);
        }
        StatsSnapshot::parse_kv(&block)
    }

    pub fn quit(&mut self) -> Result<()> {
        let _ = self.roundtrip("QUIT")?;
        Ok(())
    }
}

fn parse_ok(reply: &str) -> Result<(i64, Vec<f32>)> {
    if !reply.starts_with("OK ") {
        return Err(Error::Server(format!("server said: {reply}")));
    }
    let mut winner = -1i64;
    let mut times = Vec::new();
    for field in reply[3..].split(' ') {
        if let Some(w) = field.strip_prefix("winner=") {
            winner = w
                .parse()
                .map_err(|e| Error::Server(format!("bad winner: {e}")))?;
        } else if let Some(ts) = field.strip_prefix("times=") {
            times = ts
                .split(',')
                .map(|s| {
                    s.parse::<f32>()
                        .map_err(|e| Error::Server(format!("bad time: {e}")))
                })
                .collect::<Result<_>>()?;
        }
    }
    Ok((winner, times))
}

fn parse_ok_sparse(reply: &str) -> Result<(i64, Vec<(usize, f32)>)> {
    if !reply.starts_with("OK ") {
        return Err(Error::Server(format!("server said: {reply}")));
    }
    let mut winner = -1i64;
    let mut spikes = Vec::new();
    for field in reply[3..].split(' ') {
        if let Some(w) = field.strip_prefix("winner=") {
            winner = w
                .parse()
                .map_err(|e| Error::Server(format!("bad winner: {e}")))?;
        } else if let Some(ts) = field.strip_prefix("spikes=") {
            spikes = volley::parse_pairs(ts)?;
        }
    }
    Ok((winner, spikes))
}

/// v2 framed-protocol client: HELLO/ACK negotiation on connect, typed
/// [`Request`]/[`Response`] calls, and pipelining via
/// [`FramedClient::call_many`] (bounded in-flight windows, one socket
/// flush per window).
pub struct FramedClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    /// negotiated protocol version
    pub version: u16,
    /// column geometry from the ACK
    pub n: usize,
    pub c: usize,
    pub t_max: usize,
}

impl FramedClient {
    pub fn connect(addr: &str) -> Result<FramedClient> {
        FramedClient::connect_with(addr, &ClientConfig::default())
    }

    pub fn connect_with(addr: &str, cfg: &ClientConfig) -> Result<FramedClient> {
        let stream = connect_stream(addr, cfg)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        frame::write_frame(
            &mut writer,
            frame::FrameType::Hello,
            &frame::encode_hello(frame::MIN_VERSION, frame::VERSION),
        )?;
        writer.flush()?;
        let (ty, payload) = frame::read_frame(&mut reader)?
            .ok_or_else(|| Error::Proto("server closed during handshake".into()))?;
        let ack = match ty {
            frame::FrameType::Ack => {
                let ack = frame::decode_ack(&payload)?;
                // an ACK outside the window we offered means a broken
                // (or hostile) peer — refusing here keeps the version
                // gate in call_many honest (the python twin's
                // parse_ack rejects out-of-window versions the same way)
                if !(frame::MIN_VERSION..=frame::VERSION).contains(&ack.version) {
                    return Err(Error::Proto(format!(
                        "server ACKed unsupported protocol version {}",
                        ack.version
                    )));
                }
                ack
            }
            frame::FrameType::Response => {
                // the server's typed rejection (e.g. no common version)
                let resp = frame::decode_response(&payload)?;
                let msg = match resp.outcome {
                    Outcome::Error(e) => e,
                    other => format!("unexpected handshake response {other:?}"),
                };
                return Err(Error::Proto(msg));
            }
            other => {
                return Err(Error::Proto(format!(
                    "unexpected handshake frame {other:?}"
                )))
            }
        };
        Ok(FramedClient {
            reader,
            writer,
            next_id: 1,
            version: ack.version,
            n: ack.n as usize,
            c: ack.c as usize,
            t_max: ack.t_max as usize,
        })
    }

    fn assign_id(&mut self, req: &mut Request) {
        if req.id == 0 {
            req.id = self.next_id;
            self.next_id += 1;
        }
    }

    /// One request, one response (ids matched).
    pub fn call(&mut self, req: Request) -> Result<Response> {
        let mut responses = self.call_many(vec![req])?;
        responses
            .pop()
            .ok_or_else(|| Error::Proto("no response".into()))
    }

    /// How many requests [`call_many`](FramedClient::call_many) keeps
    /// in flight per window (the count half of the bound; windows are
    /// also capped at [`PIPELINE_WINDOW_BYTES`] of encoded frames, so
    /// large multi-volley requests shrink the window automatically).
    pub const MAX_IN_FLIGHT: usize = PIPELINE_WINDOW;

    /// Pipelined calls: requests are encoded and written in bounded
    /// windows — at most [`MAX_IN_FLIGHT`](FramedClient::MAX_IN_FLIGHT)
    /// requests / [`PIPELINE_WINDOW_BYTES`] encoded bytes, one socket
    /// flush per window, then that window's responses are collected —
    /// so arbitrarily long or large request lists never deadlock
    /// against the server's serial response writes. Responses arrive
    /// in request order; each id is checked against its request.
    pub fn call_many(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let mut responses = Vec::with_capacity(reqs.len());
        let mut window = Vec::with_capacity(Self::MAX_IN_FLIGHT);
        let mut reqs = reqs.into_iter().peekable();
        while reqs.peek().is_some() {
            let mut wire = Vec::new();
            window.clear();
            while window.len() < Self::MAX_IN_FLIGHT && wire.len() < PIPELINE_WINDOW_BYTES {
                let Some(mut req) = reqs.next() else { break };
                // v3 constructs must not reach a v2-negotiated peer —
                // it would reject the flags/op; fail typed client-side
                if self.version < 3
                    && (req.opts.model.is_some()
                        || req.gates.is_some()
                        || req.opts.trace.is_some()
                        || matches!(req.op, Op::Admin(_)))
                {
                    return Err(Error::Proto(format!(
                        "negotiated protocol v{} cannot carry model routing, admin ops, \
                         learn gates or trace ids",
                        self.version
                    )));
                }
                self.assign_id(&mut req);
                window.push(req.id);
                frame::write_frame(
                    &mut wire,
                    frame::FrameType::Request,
                    &frame::encode_request(&req)?,
                )?;
            }
            self.writer.write_all(&wire)?;
            self.writer.flush()?;
            for &want in &window {
                let (ty, payload) = frame::read_frame(&mut self.reader)?
                    .ok_or_else(|| Error::Proto("server closed mid-pipeline".into()))?;
                if ty != frame::FrameType::Response {
                    return Err(Error::Proto(format!("unexpected frame type {ty:?}")));
                }
                let resp = frame::decode_response(&payload)?;
                if resp.id != want && resp.id != 0 {
                    return Err(Error::Proto(format!(
                        "response id {} does not match request id {want}",
                        resp.id
                    )));
                }
                responses.push(resp);
            }
        }
        Ok(responses)
    }

    /// Legacy-shaped single-volley inference (winner, dense times).
    pub fn infer(&mut self, volley: &[f32]) -> Result<(i64, Vec<f32>)> {
        let resp = self.call(Request::infer(vec![SpikeVolley::dense(volley.to_vec())]))?;
        single_result(resp)
    }

    /// Legacy-shaped single-volley learning step.
    pub fn learn(&mut self, volley: &[f32]) -> Result<(i64, Vec<f32>)> {
        let resp = self.call(Request::learn(vec![SpikeVolley::dense(volley.to_vec())]))?;
        single_result(resp)
    }

    /// Multi-volley batch inference in a single frame.
    pub fn infer_batch(&mut self, volleys: Vec<SpikeVolley>) -> Result<Vec<VolleyResult>> {
        let resp = self.call(Request::infer(volleys))?;
        Ok(resp.results()?.to_vec())
    }

    /// Multi-volley batch learning step in a single frame.
    pub fn learn_batch(&mut self, volleys: Vec<SpikeVolley>) -> Result<Vec<VolleyResult>> {
        let resp = self.call(Request::learn(volleys))?;
        Ok(resp.results()?.to_vec())
    }

    pub fn stats(&mut self) -> Result<StatsSnapshot> {
        let resp = self.call(Request::op(Op::Stats))?;
        match resp.outcome {
            Outcome::Stats(s) => Ok(s),
            Outcome::Error(e) => Err(Error::Server(e)),
            other => Err(Error::Proto(format!("expected stats, got {other:?}"))),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        let resp = self.call(Request::op(Op::Ping))?;
        match resp.outcome {
            Outcome::Pong => Ok(()),
            other => Err(Error::Proto(format!("expected pong, got {other:?}"))),
        }
    }

    pub fn quit(&mut self) -> Result<()> {
        let resp = self.call(Request::op(Op::Quit))?;
        match resp.outcome {
            Outcome::Bye => Ok(()),
            other => Err(Error::Proto(format!("expected bye, got {other:?}"))),
        }
    }

    // ------------------------------------------ registry admin (v3)

    /// One admin round-trip to a typed [`AdminReply`] (an error
    /// outcome becomes the typed server error). Public because the
    /// distributed shard tier drives provisioning and replication
    /// through raw [`ModelCmd`]s ([`crate::dist`]).
    pub fn call_admin(&mut self, cmd: ModelCmd) -> Result<AdminReply> {
        let resp = self.call(Request::admin(cmd))?;
        resp.admin().cloned()
    }

    /// List the registry's models (name, geometry, θ, seed, default).
    pub fn models(&mut self) -> Result<Vec<ModelInfo>> {
        match self.call_admin(ModelCmd::List)? {
            AdminReply::Models(ms) => Ok(ms),
            other => Err(Error::Proto(format!("expected model list, got {other:?}"))),
        }
    }

    /// Create (and start serving) a new named model on the server.
    pub fn create_model(
        &mut self,
        name: &str,
        n: usize,
        theta: f32,
        seed: u64,
    ) -> Result<ModelInfo> {
        let cmd = ModelCmd::Create {
            name: name.into(),
            n,
            theta,
            seed,
        };
        match self.call_admin(cmd)? {
            AdminReply::Models(mut ms) if ms.len() == 1 => Ok(ms.remove(0)),
            other => Err(Error::Proto(format!("expected new model row, got {other:?}"))),
        }
    }

    /// Checkpoint a model's weights server-side (`<ckpt_dir>/<name>.ckpt`).
    pub fn save_model(&mut self, name: &str) -> Result<String> {
        match self.call_admin(ModelCmd::Save { name: name.into() })? {
            AdminReply::Ok(receipt) => Ok(receipt),
            other => Err(Error::Proto(format!("expected receipt, got {other:?}"))),
        }
    }

    /// Hot-swap a model's weights from its server-side checkpoint.
    pub fn load_model(&mut self, name: &str) -> Result<String> {
        match self.call_admin(ModelCmd::Load { name: name.into() })? {
            AdminReply::Ok(receipt) => Ok(receipt),
            other => Err(Error::Proto(format!("expected receipt, got {other:?}"))),
        }
    }

    /// Stop serving a (non-default) model.
    pub fn unload_model(&mut self, name: &str) -> Result<()> {
        match self.call_admin(ModelCmd::Unload { name: name.into() })? {
            AdminReply::Ok(_) => Ok(()),
            other => Err(Error::Proto(format!("expected receipt, got {other:?}"))),
        }
    }

    /// Single-volley inference routed to a named model. The volley
    /// width is the named model's `n`, which may differ from
    /// [`FramedClient::n`] (the default model's).
    pub fn infer_model(&mut self, model: &str, volley: &[f32]) -> Result<(i64, Vec<f32>)> {
        let req =
            Request::infer(vec![SpikeVolley::dense(volley.to_vec())]).with_model(model);
        single_result(self.call(req)?)
    }

    /// Single-volley learning step routed to a named model.
    pub fn learn_model(&mut self, model: &str, volley: &[f32]) -> Result<(i64, Vec<f32>)> {
        let req =
            Request::learn(vec![SpikeVolley::dense(volley.to_vec())]).with_model(model);
        single_result(self.call(req)?)
    }

    /// Gated learning step routed to a named model — the distributed
    /// two-phase protocol's phase 2 ([`Request::with_gates`]): the
    /// caller supplies the global STDP gates, one f32 per
    /// (volley, column) of the addressed model, and the host applies
    /// exactly them to its slice.
    pub fn learn_gated(
        &mut self,
        model: &str,
        volleys: Vec<SpikeVolley>,
        gates: Vec<f32>,
    ) -> Result<Vec<VolleyResult>> {
        let req = Request::learn(volleys).with_model(model).with_gates(gates);
        let resp = self.call(req)?;
        Ok(resp.results()?.to_vec())
    }

    /// Snapshot the server process's captured trace ring as CWKT bytes
    /// ([`crate::obs::decode_traces`] parses them). Non-destructive:
    /// the ring keeps its spans until capacity recycles them.
    pub fn fetch_trace(&mut self) -> Result<Vec<u8>> {
        match self.call_admin(ModelCmd::FetchTrace)? {
            AdminReply::Ckpt(bytes) => Ok(bytes),
            other => Err(Error::Proto(format!("expected trace bytes, got {other:?}"))),
        }
    }

    /// The server process's metrics as Prometheus text exposition —
    /// the same body its `/metrics` endpoint serves
    /// (`crate::obs::telemetry`, DESIGN.md §2.9).
    pub fn fetch_metrics(&mut self) -> Result<String> {
        match self.call_admin(ModelCmd::FetchMetrics)? {
            AdminReply::Ckpt(bytes) => String::from_utf8(bytes)
                .map_err(|_| Error::Proto("metrics exposition is not utf8".into())),
            other => Err(Error::Proto(format!(
                "expected metrics bytes, got {other:?}"
            ))),
        }
    }

    /// The server process's current health verdict
    /// (`state=`/`reason=` lines; parse with
    /// `crate::obs::telemetry::HealthReport::parse`).
    pub fn fetch_health(&mut self) -> Result<String> {
        match self.call_admin(ModelCmd::FetchHealth)? {
            AdminReply::Ckpt(bytes) => String::from_utf8(bytes)
                .map_err(|_| Error::Proto("health report is not utf8".into())),
            other => Err(Error::Proto(format!(
                "expected health bytes, got {other:?}"
            ))),
        }
    }

    /// Typed stats for one model only (plain, unprefixed keys).
    pub fn stats_model(&mut self, model: &str) -> Result<StatsSnapshot> {
        let resp = self.call(Request::op(Op::Stats).with_model(model))?;
        match resp.outcome {
            Outcome::Stats(s) => Ok(s),
            Outcome::Error(e) => Err(Error::Server(e)),
            other => Err(Error::Proto(format!("expected stats, got {other:?}"))),
        }
    }
}

fn single_result(resp: Response) -> Result<(i64, Vec<f32>)> {
    let rs = resp.results()?;
    let r = rs
        .first()
        .ok_or_else(|| Error::Proto("empty result set".into()))?;
    Ok((r.winner.map(|w| w as i64).unwrap_or(-1), r.times.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ok_replies() {
        let (w, t) = parse_ok("OK winner=2 times=1,16,3").unwrap();
        assert_eq!(w, 2);
        assert_eq!(t, vec![1.0, 16.0, 3.0]);
        let (w, _) = parse_ok("OK winner=-1 times=16").unwrap();
        assert_eq!(w, -1);
        assert!(parse_ok("ERR nope").is_err());
    }

    #[test]
    fn parse_sparse_replies() {
        let (w, spikes) = parse_ok_sparse("OK winner=2 spikes=0:4,2:2").unwrap();
        assert_eq!(w, 2);
        assert_eq!(spikes, vec![(0, 4.0), (2, 2.0)]);
        let (w, spikes) = parse_ok_sparse("OK winner=-1 spikes=-").unwrap();
        assert_eq!(w, -1);
        assert!(spikes.is_empty());
        assert!(parse_ok_sparse("ERR nope").is_err());
    }

    #[test]
    fn client_config_defaults_bounded() {
        let cfg = ClientConfig::default();
        assert!(cfg.connect_timeout <= Duration::from_secs(30));
        assert!(cfg.read_timeout.is_some());
        assert!(cfg.write_timeout.is_some());
    }

    #[test]
    fn connect_times_out_against_black_hole() {
        // RFC 5737 TEST-NET address: connect can't succeed; the timeout
        // must bound the wait.
        let cfg = ClientConfig {
            connect_timeout: Duration::from_millis(150),
            ..ClientConfig::default()
        };
        let t0 = Instant::now();
        let r = Client::connect_with("192.0.2.1:9", &cfg);
        assert!(r.is_err());
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "connect hung {:?}",
            t0.elapsed()
        );
    }
}
