//! The `CWKS` shard manifest: one file tying a sharded model's K
//! per-shard `CWKP` weight checkpoints into a single atomic unit.
//!
//! A sharded model cannot persist as one `CWKP` file without
//! serializing every shard through one writer, and it cannot persist as
//! K naked files without risking a load that mixes save generations.
//! The manifest solves both: each shard engine writes its own `CWKP`
//! slice, and the manifest — written **last** — records the partition
//! plus a CRC-32 of every shard file's complete bytes, so the loader
//! can prove all K files belong to the same save before touching a
//! live engine (DESIGN.md §2.4):
//!
//! ```text
//! manifest := magic u32 ("CWKS") | schema u16
//!             | n u32 | c u32 | t_max u32
//!             | theta f32 | seed u64
//!             | k u32
//!             | k × (start u32 | end u32 | file_crc u32)
//!             | crc32 u32                      (over all prior bytes)
//! ```
//!
//! Conventions match [`crate::registry::checkpoint`]: big-endian
//! integers, IEEE-754 bit-pattern floats, zlib-polynomial CRC-32, and
//! an atomic temp-file + rename save. The python wire twin
//! (`test_shard_manifest_golden_bytes` in
//! `python/tests/test_proto_frames.py`) shares a golden byte vector
//! with `rust/tests/shard.rs`. Shard files are addressed by
//! **position**, not by stored paths — [`shard_path`] derives
//! `<name>.shard<i>.<crc>.ckpt` from the manifest's own path and its
//! recorded per-file CRCs, so a manifest
//! can never point outside its directory.

use crate::error::{Error, Result};
use crate::registry::checkpoint::{crc32, write_atomic};
use std::path::{Path, PathBuf};

/// Shard manifest magic: `b"CWKS"`.
pub const SHARD_MAGIC: [u8; 4] = *b"CWKS";
/// The manifest schema this build reads and writes.
pub const SHARD_SCHEMA: u16 = 1;
/// Hard cap on the shard count — a hostile header must not become an
/// allocation (no real column config approaches this).
pub const MAX_SHARDS: u32 = 1 << 12;

/// Fixed header size (magic..k inclusive) before the entry table.
const HEADER: usize = 34;
/// Bytes per shard entry.
const ENTRY: usize = 12;

/// One shard's row in the manifest: the columns it covers and the
/// CRC-32 of its `CWKP` file's complete bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    pub start: u32,
    pub end: u32,
    pub file_crc: u32,
}

/// The parsed `CWKS` manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    /// column input width
    pub n: u32,
    /// total output columns across all shards
    pub c: u32,
    pub t_max: u32,
    /// threshold the weights were learned under (provenance)
    pub theta: f32,
    /// weight-init seed of the originating instance (provenance)
    pub seed: u64,
    /// per-shard column ranges + file CRCs, in shard order
    pub shards: Vec<ShardEntry>,
}

impl ShardManifest {
    /// Serialize to the on-disk byte layout.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        validate_partition(self.c, &self.shards)?;
        let mut p = Vec::with_capacity(HEADER + self.shards.len() * ENTRY + 4);
        p.extend_from_slice(&SHARD_MAGIC);
        p.extend_from_slice(&SHARD_SCHEMA.to_be_bytes());
        p.extend_from_slice(&self.n.to_be_bytes());
        p.extend_from_slice(&self.c.to_be_bytes());
        p.extend_from_slice(&self.t_max.to_be_bytes());
        p.extend_from_slice(&self.theta.to_bits().to_be_bytes());
        p.extend_from_slice(&self.seed.to_be_bytes());
        p.extend_from_slice(&(self.shards.len() as u32).to_be_bytes());
        for s in &self.shards {
            p.extend_from_slice(&s.start.to_be_bytes());
            p.extend_from_slice(&s.end.to_be_bytes());
            p.extend_from_slice(&s.file_crc.to_be_bytes());
        }
        let crc = crc32(&p);
        p.extend_from_slice(&crc.to_be_bytes());
        Ok(p)
    }

    /// Parse and verify. Every malformed input — short file, bad
    /// magic/schema, CRC failure, shard count out of bounds, a table
    /// that is not a contiguous ascending partition of `0..c` — is a
    /// typed [`Error::Checkpoint`].
    pub fn from_bytes(b: &[u8]) -> Result<ShardManifest> {
        if b.len() < HEADER + 4 {
            return Err(Error::Checkpoint(format!(
                "truncated shard manifest: {} bytes",
                b.len()
            )));
        }
        let (body, tail) = b.split_at(b.len() - 4);
        let stored = u32::from_be_bytes([tail[0], tail[1], tail[2], tail[3]]);
        let actual = crc32(body);
        if stored != actual {
            return Err(Error::Checkpoint(format!(
                "crc mismatch: file says {stored:#010x}, bytes hash to {actual:#010x}"
            )));
        }
        if body[..4] != SHARD_MAGIC {
            return Err(Error::Checkpoint(format!(
                "bad magic {:02x?} (want {SHARD_MAGIC:02x?})",
                &body[..4]
            )));
        }
        let schema = u16::from_be_bytes([body[4], body[5]]);
        if schema != SHARD_SCHEMA {
            return Err(Error::Checkpoint(format!(
                "unknown shard-manifest schema {schema} (this build reads {SHARD_SCHEMA})"
            )));
        }
        let u32_at = |off: usize| {
            u32::from_be_bytes([body[off], body[off + 1], body[off + 2], body[off + 3]])
        };
        let n = u32_at(6);
        let c = u32_at(10);
        let t_max = u32_at(14);
        let theta = f32::from_bits(u32_at(18));
        let seed = u64::from_be_bytes([
            body[22], body[23], body[24], body[25], body[26], body[27], body[28], body[29],
        ]);
        let k = u32_at(30);
        if k == 0 || k > MAX_SHARDS {
            return Err(Error::Checkpoint(format!(
                "shard count {k} outside 1..={MAX_SHARDS}"
            )));
        }
        if body.len() != HEADER + (k as usize) * ENTRY {
            return Err(Error::Checkpoint(format!(
                "shard table is {} bytes, header promises {}",
                body.len() - HEADER,
                (k as usize) * ENTRY
            )));
        }
        let shards: Vec<ShardEntry> = (0..k as usize)
            .map(|i| {
                let off = HEADER + i * ENTRY;
                ShardEntry {
                    start: u32_at(off),
                    end: u32_at(off + 4),
                    file_crc: u32_at(off + 8),
                }
            })
            .collect();
        validate_partition(c, &shards)?;
        Ok(ShardManifest {
            n,
            c,
            t_max,
            theta,
            seed,
            shards,
        })
    }

    /// Write atomically (temp file + `sync_all` + rename), like
    /// [`crate::registry::checkpoint::Checkpoint::save`].
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &self.to_bytes()?)
    }

    /// Read and verify a shard-manifest file.
    pub fn read(path: &Path) -> Result<ShardManifest> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Checkpoint(format!("read {}: {e}", path.display())))?;
        ShardManifest::from_bytes(&bytes)
            .map_err(|e| Error::Checkpoint(format!("{}: {e}", path.display())))
    }
}

/// The table must be a contiguous ascending partition of `0..c` —
/// anything else means mixed plans or forged bytes.
fn validate_partition(c: u32, shards: &[ShardEntry]) -> Result<()> {
    let mut expect = 0u32;
    for (i, s) in shards.iter().enumerate() {
        if s.start != expect || s.end <= s.start {
            return Err(Error::Checkpoint(format!(
                "shard {i} covers {}..{}, expected a contiguous range from {expect}",
                s.start, s.end
            )));
        }
        expect = s.end;
    }
    if expect != c {
        return Err(Error::Checkpoint(format!(
            "shard table covers 0..{expect}, manifest promises c={c}"
        )));
    }
    Ok(())
}

/// Shard `i`'s `CWKP` file for the manifest at `path`:
/// `<dir>/<stem>.shard<i>.<crc:08x>.ckpt` — derived from the
/// manifest's own path and the entry's recorded file CRC, never
/// stored, so a manifest cannot name files outside its own directory.
///
/// The CRC in the **name** is what makes a sharded save crash-safe:
/// a new generation's shard files land under fresh names while the
/// old generation's files stay untouched, and the manifest rename is
/// the single atomic commit point — a crash mid-save leaves the old
/// manifest pointing at the complete old set (plus harmless orphans
/// that [`sweep_stale_shards`] collects on the next save).
pub fn shard_path(path: &Path, i: usize, file_crc: u32) -> PathBuf {
    let stem = manifest_stem(path);
    let name = format!("{stem}.shard{i}.{file_crc:08x}.ckpt");
    match path.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => dir.join(name),
        _ => PathBuf::from(name),
    }
}

fn manifest_stem(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "model".into())
}

/// Best-effort removal of shard files from superseded save generations:
/// everything matching `<stem>.shard<i>.<crc>.ckpt` that the committed
/// manifest does not reference. Failures are ignored — orphans are
/// harmless (never referenced) and the next save sweeps again.
pub fn sweep_stale_shards(path: &Path, keep: &ShardManifest) {
    let stem = manifest_stem(path);
    let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) else {
        return;
    };
    let live: Vec<String> = keep
        .shards
        .iter()
        .enumerate()
        .map(|(i, s)| format!("{stem}.shard{i}.{:08x}.ckpt", s.file_crc))
        .collect();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let prefix = format!("{stem}.shard");
    for e in entries.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if name.starts_with(&prefix)
            && name.ends_with(".ckpt")
            && !live.iter().any(|l| *l == name)
        {
            let _ = std::fs::remove_file(e.path());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ShardManifest {
        ShardManifest {
            n: 16,
            c: 8,
            t_max: 16,
            theta: 6.0,
            seed: 11,
            shards: vec![
                ShardEntry { start: 0, end: 3, file_crc: 0x1111_1111 },
                ShardEntry { start: 3, end: 6, file_crc: 0x2222_2222 },
                ShardEntry { start: 6, end: 8, file_crc: 0x3333_3333 },
            ],
        }
    }

    #[test]
    fn byte_roundtrip_is_identity() {
        let m = sample();
        let bytes = m.to_bytes().unwrap();
        assert_eq!(ShardManifest::from_bytes(&bytes).unwrap(), m);
        assert_eq!(&bytes[..4], b"CWKS");
        assert_eq!(bytes.len(), HEADER + 3 * ENTRY + 4);
    }

    #[test]
    fn every_truncation_and_any_bit_flip_rejected() {
        let bytes = sample().to_bytes().unwrap();
        for cut in 0..bytes.len() {
            assert!(ShardManifest::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        for i in 0..bytes.len() {
            let mut flipped = bytes.clone();
            flipped[i] ^= 0x01;
            assert!(
                ShardManifest::from_bytes(&flipped).is_err(),
                "flip at byte {i} went undetected"
            );
        }
        let mut noisy = bytes.clone();
        noisy.push(0);
        assert!(ShardManifest::from_bytes(&noisy).is_err());
    }

    #[test]
    fn partition_must_tile_the_columns() {
        let mut m = sample();
        m.shards[1].start = 4; // gap after shard 0
        assert!(m.to_bytes().is_err());
        let mut m = sample();
        m.shards[2].end = 7; // does not reach c
        assert!(m.to_bytes().is_err());
        let mut m = sample();
        m.shards[0].end = 0; // empty shard
        assert!(m.to_bytes().is_err());
        let mut m = sample();
        m.shards.clear(); // covers nothing
        assert!(m.to_bytes().is_err());

        // a forged shard count is rejected before any allocation
        // (crc re-forged so the count check is what fires)
        let mut bytes = sample().to_bytes().unwrap();
        bytes[30..34].copy_from_slice(&(MAX_SHARDS + 1).to_be_bytes());
        let len = bytes.len();
        let crc = crc32(&bytes[..len - 4]);
        bytes[len - 4..].copy_from_slice(&crc.to_be_bytes());
        assert!(ShardManifest::from_bytes(&bytes).is_err());
    }

    #[test]
    fn save_read_and_shard_paths() {
        let dir = std::env::temp_dir().join(format!(
            "catwalk-cwks-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = dir.join("m.ckpt");
        let m = sample();
        m.save(&path).unwrap();
        assert_eq!(ShardManifest::read(&path).unwrap(), m);
        // shard file names are content-addressed by the recorded CRC
        assert_eq!(
            shard_path(&path, 0, 0x1111_1111),
            dir.join("m.shard0.11111111.ckpt")
        );
        assert_eq!(
            shard_path(&path, 2, 0xAB),
            dir.join("m.shard2.000000ab.ckpt")
        );
        assert_eq!(
            shard_path(Path::new("bare.ckpt"), 1, 1),
            PathBuf::from("bare.shard1.00000001.ckpt")
        );
        let err = ShardManifest::read(&dir.join("absent.ckpt")).unwrap_err();
        assert!(err.to_string().contains("absent.ckpt"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The stale-shard sweep removes superseded generations but never
    /// the files the committed manifest references, and never another
    /// model's files.
    #[test]
    fn sweep_keeps_live_generation_only() {
        let dir = std::env::temp_dir().join(format!(
            "catwalk-cwks-sweep-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let m = sample();
        // live generation + an orphan from an older save + a sibling
        // model whose name shares the prefix characters
        for (i, s) in m.shards.iter().enumerate() {
            std::fs::write(shard_path(&path, i, s.file_crc), b"live").unwrap();
        }
        std::fs::write(dir.join("m.shard0.deadbeef.ckpt"), b"stale").unwrap();
        std::fs::write(dir.join("m2.shard0.deadbeef.ckpt"), b"other model").unwrap();
        sweep_stale_shards(&path, &m);
        for (i, s) in m.shards.iter().enumerate() {
            assert!(shard_path(&path, i, s.file_crc).exists(), "live shard {i}");
        }
        assert!(!dir.join("m.shard0.deadbeef.ckpt").exists(), "stale swept");
        assert!(dir.join("m2.shard0.deadbeef.ckpt").exists(), "other model kept");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
