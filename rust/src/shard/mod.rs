//! Sharded-model execution: scatter/gather column sharding of one TNN
//! model across K parallel engines — in one process or across hosts.
//!
//! The paper's column is an array of independent RNL neurons — each
//! output column owns a private weight row and sees the full input
//! volley; the only cross-column coupling is the final WTA stage. The
//! TNN microarchitecture framework line scales columns by replicating
//! independent neuron lanes behind one shared input bus, and this module
//! is that shape in the serving stack (DESIGN.md §2.4): a model's `c`
//! output columns partition into K contiguous shards, each served by
//! its own engine, with one thin scatter/gather layer re-running
//! the global winner selection over the concatenated per-shard times:
//!
//! ```text
//!              ┌ shard 0: cols 0..6   ShardTransport (inproc or tcp) ┐
//!  volley ──►  ├ shard 1: cols 6..11  ShardTransport (inproc or tcp) ┤ ──► gather:
//!  (scatter    └ shard 2: cols 11..16 ShardTransport (inproc or tcp) ┘     concat times,
//!   to all)                                                               global argmin
//! ```
//!
//! Where a shard *runs* is behind [`crate::dist::ShardTransport`]
//! (DESIGN.md §2.7): [`ShardedModel::open`] builds in-process shards
//! (a column-range engine plus its private batcher, the PR 5 shape),
//! [`ShardedModel::open_remote`] drives `repro serve` shard hosts over
//! the framed v3 codec. Everything in this file — the scatter, the
//! gather, the two-phase learn, the checkpoint format — is
//! transport-agnostic, which is what makes the TCP path bit-identical
//! to the in-process one.
//!
//! **Bit-identity contract.** A [`ShardedModel`] produces results
//! byte-for-byte equal to the unsharded model it partitions
//! (`rust/tests/shard.rs` and `rust/tests/dist.rs` gate this over TCP
//! on both codecs and both transports):
//!
//! * *Weights*: every shard initializes from the full `c × n` RNG walk
//!   and keeps its slice ([`crate::coordinator::TnnHandle::open_columns`]),
//!   so shard row `r` equals unsharded row `range.start + r`.
//! * *Forward*: first-crossing times are per-column independent; the
//!   gather step concatenates them in shard order (contiguous ranges
//!   preserve column indices) and re-runs the WTA argmin — same
//!   strictly-less scan, same lowest-index tie-break.
//! * *Learn*: the STDP gate is **global** — `1` for the global winner,
//!   `1` everywhere on a globally silent row, `0` otherwise — so
//!   learning runs a two-phase protocol per chunk: phase 1 scatters a
//!   forward pass and gathers the global winners; phase 2 scatters a
//!   gated update ([`crate::runtime::plan::KernelPlan::stdp_gated`]) with
//!   each shard's slice of those gates. Each column's weights are
//!   touched only by its own shard, and the accumulation arithmetic is
//!   the unsharded kernel's loop restricted to the shard's rows. Over
//!   TCP the phases travel as ordinary Infer envelopes and gated Learn
//!   envelopes (`FLAG_GATES`) — the gates are computed here, once,
//!   globally, and the remote shard applies exactly them.
//!
//! Concurrency: a model-level read/write lock (the lock *around* the
//! transport vector) stands in for the atomicity one engine thread
//! gave the unsharded model. Infers, weight snapshots and checkpoint
//! saves hold it **shared** — they interleave freely but always
//! observe one consistent weight generation across all K shards.
//! Learns, weight swaps and failover hold it **exclusive**: the two
//! phases of one learn must hit every shard in the same order, no
//! infer may mix pre- and post-update shards into one reply, no
//! autosave may persist half a generation, and no request may race a
//! standby swap. A phase-2 failure on some shard (an engine shut down
//! or a host dead mid-request) errors the whole chunk; shards that
//! already applied it may then disagree until the next checkpoint load
//! or failover, exactly like a torn unsharded process death.
//!
//! Checkpoints: a sharded model persists as K `CWKP` per-shard weight
//! files tied together by one `CWKS` shard-manifest ([`manifest`]);
//! partial, missing or mismatched shard files are rejected as a unit
//! and the old weights keep serving. A remote model with standby hosts
//! additionally **replicates** every committed generation to them
//! ([`crate::dist::replicate`]) — which is what [`ShardedModel::failover`]
//! resumes a dead shard's standby from.

pub mod manifest;

use crate::coordinator::{BatcherConfig, DynamicBatcher, Metrics, TnnHandle};
use crate::dist::{replicate, InProcessShard, RetryPolicy, ShardCall, ShardTransport, TcpShard};
use crate::error::{Error, Result};
use crate::registry::checkpoint::{crc32, write_atomic, Checkpoint};
use crate::runtime::{BackendKind, Manifest, Tensor};
use crate::server::ClientConfig;
use crate::volley::{SpikeVolley, VolleyResult};
use manifest::{shard_path, ShardEntry, ShardManifest};
use std::collections::HashMap;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Deterministic partition of `c` output columns into `k` contiguous
/// shards: the first `c % k` shards take `c / k + 1` columns, the rest
/// `c / k` — so any `(c, k)` pair names exactly one layout, and a
/// checkpoint written under one plan can be validated against another.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// total output columns
    pub c: usize,
    /// shard count (`1..=c`)
    pub k: usize,
}

impl ShardPlan {
    pub fn new(c: usize, k: usize) -> Result<ShardPlan> {
        if k == 0 || k > c {
            return Err(Error::Coordinator(format!(
                "shard count {k} must be in 1..={c} (one column per shard at most)"
            )));
        }
        Ok(ShardPlan { c, k })
    }

    /// Columns shard `i` owns.
    pub fn range(&self, i: usize) -> Range<usize> {
        debug_assert!(i < self.k);
        let (base, rem) = (self.c / self.k, self.c % self.k);
        let start = i * base + i.min(rem);
        start..start + base + usize::from(i < rem)
    }

    /// Every shard's range, in shard order (their concatenation is
    /// exactly `0..c`).
    pub fn ranges(&self) -> Vec<Range<usize>> {
        (0..self.k).map(|i| self.range(i)).collect()
    }
}

/// The remote-tier half of a [`ShardedModel`]: how to provision
/// replacement transports and where the standby hosts are. `None` for
/// an in-process model, which has no hosts to fail over to.
struct RemoteState {
    /// the model name shard hosts know the slices under
    name: String,
    client: ClientConfig,
    retry: RetryPolicy,
    /// Standby host pool, consumed LIFO by [`ShardedModel::failover`].
    standbys: Mutex<Vec<String>>,
    /// Last checkpoint generation each standby acknowledged — what the
    /// `replication_lag_generations` gauge is computed from.
    replicated: Mutex<HashMap<String, u64>>,
}

/// K column-shard transports behind one model-shaped face: same
/// `infer`/`learn`/`weights`/`set_weights` surface as a single
/// [`TnnHandle`] slot, same results bit for bit — whether the shards
/// are in-process engines or remote hosts.
pub struct ShardedModel {
    pub plan: ShardPlan,
    /// The K shard transports behind the model-level consistency lock.
    /// **Shared** holders (infers, weight snapshots, checkpoint saves)
    /// may interleave freely — they only read a stable weight
    /// generation — while **exclusive** holders (learns, weight swaps,
    /// failover) mutate it. Without it a concurrent infer could mix
    /// pre- and post-update shards into a reply no consistent weight
    /// matrix could produce, a learn's two phases could hit shards in
    /// different orders, an autosave could persist a torn,
    /// mixed-generation checkpoint whose fresh CRCs defeat the
    /// loader's own mixed-generation gate, and an infer could scatter
    /// onto a shard failover is half done replacing.
    shards: RwLock<Vec<Arc<dyn ShardTransport>>>,
    /// column input width
    pub n: usize,
    /// total output columns (= `plan.c`)
    pub c: usize,
    /// backend batch size
    pub b: usize,
    pub t_max: usize,
    pub theta: f32,
    pub seed: u64,
    /// executing backend of the shard engines (`"tcp"` for remote)
    pub backend: &'static str,
    pub artifacts_dir: PathBuf,
    /// Model-level counters/hists (requests, volleys, latency) — each
    /// request is counted **once** here; the per-shard transport
    /// metrics (which see every request K times) surface separately as
    /// `model.<name>.shard.<i>.*` stats rows.
    pub metrics: Arc<Metrics>,
    /// Set by [`ShardedModel::drain`]: the model is unloaded; learns
    /// (which bypass the per-shard batchers) answer with the same
    /// typed error a closed batcher gives.
    stopped: AtomicBool,
    /// Volleys per learn execution — mirrors the batcher's `max_batch`
    /// so a serial client's learn chunking matches the unsharded path.
    learn_chunk: usize,
    /// Remote provisioning + standby pool; `None` in-process.
    remote: Option<RemoteState>,
    /// Checkpoint generation counter: bumped once per committed
    /// [`ShardedModel::save_checkpoints`]; replication lag is measured
    /// in these units.
    generation: AtomicU64,
}

/// Owned per-shard copies of one scatter payload: K−1 clones plus the
/// original moved into the last slot — both scatter sites (infer and
/// learn phase 2) share this so they cannot drift apart.
fn scatter_payloads<T: Clone>(payload: Vec<T>, k: usize) -> Vec<Vec<T>> {
    let mut out = Vec::with_capacity(k);
    for _ in 1..k {
        out.push(payload.clone());
    }
    out.push(payload);
    out
}

impl ShardedModel {
    /// Open K in-process column-shard engines over the manifest
    /// geometry for `n`. Every shard shares `(n, theta, seed)` — the
    /// init RNG walk is the full matrix in each engine, sliced to the
    /// shard's rows.
    pub fn open(
        dir: impl AsRef<Path>,
        n: usize,
        theta: f32,
        seed: u64,
        k: usize,
        batcher: BatcherConfig,
    ) -> Result<ShardedModel> {
        let dir = dir.as_ref().to_path_buf();
        let kind = BackendKind::from_env()?;
        let m = Manifest::load_or_default(&dir, kind.requires_artifacts())?;
        let entry = m
            .entries
            .iter()
            .find(|e| e.kind == "forward" && e.n == n)
            .ok_or_else(|| Error::Runtime(format!("no forward artifact for n={n}")))?;
        let plan = ShardPlan::new(entry.c, k)?;
        let mut shards: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(k);
        let mut geo = None;
        for range in plan.ranges() {
            let handle = TnnHandle::open_columns(&dir, n, theta, seed, range.clone())?;
            geo.get_or_insert((handle.b, handle.t_max, handle.backend));
            let infer = DynamicBatcher::start(handle.clone(), batcher);
            shards.push(Arc::new(InProcessShard::new(handle, infer, range)));
        }
        let (b, t_max, backend) = geo.expect("a plan has at least one shard");
        Ok(ShardedModel {
            n,
            c: plan.c,
            b,
            t_max,
            theta,
            seed,
            backend,
            artifacts_dir: dir,
            plan,
            metrics: Arc::new(Metrics::new()),
            shards: RwLock::new(shards),
            stopped: AtomicBool::new(false),
            learn_chunk: batcher.max_batch,
            remote: None,
            generation: AtomicU64::new(0),
        })
    }

    /// Open the model's K column shards on remote `repro serve` hosts
    /// (one host per shard, geometry from the local artifacts
    /// manifest): connect with backoff, provision slot `<name>-s<i>`
    /// on host `i` ([`crate::proto::ModelCmd::CreateColumns`] — the
    /// host resumes the slice from its replicated generation if it
    /// holds one), and serve through [`TcpShard`] transports.
    /// `standbys` is the failover pool; `batcher` only contributes the
    /// learn chunk size, for parity with the in-process path.
    #[allow(clippy::too_many_arguments)]
    pub fn open_remote(
        dir: impl AsRef<Path>,
        name: &str,
        n: usize,
        theta: f32,
        seed: u64,
        hosts: &[String],
        standbys: Vec<String>,
        client: ClientConfig,
        retry: RetryPolicy,
        batcher: BatcherConfig,
    ) -> Result<ShardedModel> {
        let dir = dir.as_ref().to_path_buf();
        let kind = BackendKind::from_env()?;
        let m = Manifest::load_or_default(&dir, kind.requires_artifacts())?;
        let entry = m
            .entries
            .iter()
            .find(|e| e.kind == "forward" && e.n == n)
            .ok_or_else(|| Error::Runtime(format!("no forward artifact for n={n}")))?;
        let plan = ShardPlan::new(entry.c, hosts.len())?;
        let mut shards: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(plan.k);
        for (i, host) in hosts.iter().enumerate() {
            let t = TcpShard::open(
                host,
                name,
                i,
                plan.range(i),
                n,
                m.t_max,
                theta,
                seed,
                &client,
                &retry,
            )?;
            shards.push(Arc::new(t));
        }
        Ok(ShardedModel {
            n,
            c: plan.c,
            b: entry.b,
            t_max: m.t_max,
            theta,
            seed,
            backend: "tcp",
            artifacts_dir: dir,
            plan,
            metrics: Arc::new(Metrics::new()),
            shards: RwLock::new(shards),
            stopped: AtomicBool::new(false),
            learn_chunk: batcher.max_batch,
            remote: Some(RemoteState {
                name: name.to_string(),
                client,
                retry,
                standbys: Mutex::new(standbys),
                replicated: Mutex::new(HashMap::new()),
            }),
            generation: AtomicU64::new(0),
        })
    }

    /// Shard `i`'s transport-level counters (stats rows, tests).
    pub fn shard_metrics(&self, i: usize) -> Arc<Metrics> {
        self.shards.read().unwrap()[i].metrics()
    }

    /// True when the shards live on remote hosts over the TCP
    /// transport (the shape with standbys and replication).
    pub fn is_remote(&self) -> bool {
        self.remote.is_some()
    }

    /// How many standby spares remain in the failover pool — `None`
    /// for an in-process model (failover does not apply). A remote
    /// model at `Some(0)` cannot survive another host loss; the
    /// telemetry health model reports it `standby_pool_empty`.
    pub fn standby_depth(&self) -> Option<usize> {
        self.remote
            .as_ref()
            .map(|r| r.standbys.lock().unwrap().len())
    }

    /// The committed weight generation (bumps on each replicated
    /// checkpoint push).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Acquire)
    }

    /// Indices of shards whose transport is known dead — candidates
    /// for [`ShardedModel::failover`]. Always empty in-process.
    pub fn failed_shards(&self) -> Vec<usize> {
        self.shards
            .read()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|(_, s)| s.failed())
            .map(|(i, _)| i)
            .collect()
    }

    /// Scatter a volley batch to every shard, gather the per-shard
    /// times, merge with a global winner re-selection. One `Result`
    /// per volley in request order, like the batcher. Holds the state
    /// lock **shared** for the whole scatter/gather, so every reply is
    /// computed against one consistent weight generation (concurrent
    /// infers still interleave and coalesce).
    pub fn infer(
        &self,
        volleys: Vec<SpikeVolley>,
        deadline: Option<Instant>,
    ) -> Vec<Result<VolleyResult>> {
        if volleys.is_empty() {
            return Vec::new();
        }
        let t0 = Instant::now();
        let shards = self.shards.read().unwrap();
        if self.stopped.load(Ordering::Acquire) {
            return self.all_stopped(volleys.len());
        }
        let sparse = volleys.iter().filter(|v| v.is_sparse()).count() as u64;
        self.count_request(sparse, volleys.len() as u64 - sparse);
        let k = shards.len();
        let ctx = crate::obs::current();
        // scatter: enqueue every shard before blocking on any
        let t_scatter = ctx.sampled.then(Instant::now);
        let calls: Vec<ShardCall> = shards
            .iter()
            .zip(scatter_payloads(volleys, k))
            .map(|(s, v)| s.begin_infer(v, deadline))
            .collect();
        if let Some(ts) = t_scatter {
            crate::obs::record(ctx, crate::obs::Stage::Scatter, k as u32, ts, ts.elapsed());
        }
        let t_gather = ctx.sampled.then(Instant::now);
        let parts: Vec<Vec<Result<VolleyResult>>> =
            calls.into_iter().map(|c| c.wait()).collect();
        let merged = self.gather(parts);
        if let Some(tg) = t_gather {
            crate::obs::record(ctx, crate::obs::Stage::Gather, k as u32, tg, tg.elapsed());
        }
        let ok = merged.iter().filter(|r| r.is_ok()).count() as u64;
        self.metrics.incr("volleys_inferred", ok);
        // expiries are detected at each shard's transport (which
        // counts them on the *shard* metrics, K-fold); fold them into
        // the model-level counter once, matched structurally on the
        // typed variant, so `requests_expired` stays consistent
        // between single and sharded slots
        let expired = merged
            .iter()
            .filter(|r| matches!(r, Err(Error::DeadlineExpired)))
            .count() as u64;
        if expired > 0 {
            self.metrics.incr("requests_expired", expired);
        }
        for r in &merged {
            if r.is_ok() {
                self.metrics.record("request_latency", t0.elapsed());
            }
        }
        merged
    }

    /// The per-volley reply a drained model gives — the same typed
    /// error a closed batcher produces, so unload semantics match the
    /// single-engine slot.
    fn all_stopped(&self, nvol: usize) -> Vec<Result<VolleyResult>> {
        (0..nvol)
            .map(|_| Err(Error::Coordinator("sharded model is shut down".into())))
            .collect()
    }

    /// The two-phase sharded learning step; one `Result` per volley.
    /// Chunked at the batcher's `max_batch` (the grouping a serial
    /// client's learns get from the unsharded batcher); each chunk is
    /// phase 1 (scatter forward, gather global winners) then phase 2
    /// (scatter gated updates). The exclusive lock is taken **per
    /// chunk**, not across the whole request — infers interleave
    /// between chunks exactly as the unsharded batchers interleave
    /// between learn batches, observing only whole intermediate weight
    /// generations.
    pub fn learn(
        &self,
        volleys: Vec<SpikeVolley>,
        deadline: Option<Instant>,
    ) -> Vec<Result<VolleyResult>> {
        if volleys.is_empty() {
            return Vec::new();
        }
        let t0 = Instant::now();
        // cheap pre-check; the authoritative one runs under the lock
        if self.stopped.load(Ordering::Acquire) {
            return self.all_stopped(volleys.len());
        }
        // count at submit time like the batcher does, so
        // `requests >= requests_expired` holds on every path
        let sparse = volleys.iter().filter(|v| v.is_sparse()).count() as u64;
        self.count_request(sparse, volleys.len() as u64 - sparse);
        let out = self.learn_chunks(volleys, deadline);
        // single accounting exit: chunks completed before an expiry or
        // a drain still count as learned work
        let ok = out.iter().filter(|r| r.is_ok()).count() as u64;
        self.metrics.incr("volleys_learned", ok);
        for r in &out {
            if r.is_ok() {
                self.metrics.record("request_latency", t0.elapsed());
            }
        }
        out
    }

    /// The chunk loop behind [`ShardedModel::learn`]; early returns
    /// here still flow through `learn`'s accounting.
    fn learn_chunks(
        &self,
        volleys: Vec<SpikeVolley>,
        deadline: Option<Instant>,
    ) -> Vec<Result<VolleyResult>> {
        let mut out: Vec<Result<VolleyResult>> = Vec::with_capacity(volleys.len());
        let mut rest = volleys;
        while !rest.is_empty() {
            let tail = rest.split_off(self.learn_chunk.min(rest.len()));
            let chunk = std::mem::replace(&mut rest, tail);
            let chunk_len = chunk.len();
            // a deadline bounds queue wait exactly like the batcher's
            // drain-time check: expired chunks are dropped untouched
            if deadline.is_some_and(|d| Instant::now() >= d) {
                self.metrics
                    .incr("requests_expired", (chunk_len + rest.len()) as u64);
                for _ in 0..chunk_len + rest.len() {
                    out.push(Err(Error::DeadlineExpired));
                }
                return out;
            }
            let shards = self.shards.write().unwrap();
            // checked under the lock: a learn parked on the lock while
            // drain ran must fail typed, not mutate an unloaded model
            if self.stopped.load(Ordering::Acquire) {
                out.extend(self.all_stopped(chunk_len + rest.len()));
                return out;
            }
            match self.run_learn_chunk(&shards, chunk) {
                Ok(results) => out.extend(results.into_iter().map(Ok)),
                Err(e) => {
                    let msg = e.to_string();
                    out.extend((0..chunk_len).map(|_| {
                        Err(Error::Coordinator(format!("batch failed: {msg}")))
                    }));
                }
            }
        }
        out
    }

    /// One learn chunk: forward everywhere, derive the global gates,
    /// update everywhere. The phase-2 forward pass inside the train
    /// kernel recomputes the same times phase 1 gathered (weights
    /// cannot change between phases — the caller holds the state lock
    /// exclusively), so the merged reply re-selects its winner from
    /// phase-2 times.
    fn run_learn_chunk(
        &self,
        shards: &[Arc<dyn ShardTransport>],
        chunk: Vec<SpikeVolley>,
    ) -> Result<Vec<VolleyResult>> {
        let k = shards.len();
        let rows = chunk.len();
        // phase 1: locate every row's global winner (the chunk is
        // still needed for phase 2, so every shard gets a clone here)
        let calls: Vec<ShardCall> = shards
            .iter()
            .map(|s| s.begin_forward(chunk.clone()))
            .collect::<Result<Vec<_>>>()?;
        let mut parts = Vec::with_capacity(k);
        for call in calls {
            parts.push(call.wait_all()?);
        }
        let winners: Vec<Option<usize>> = (0..rows)
            .map(|r| {
                let mut times = Vec::with_capacity(self.c);
                for p in &parts {
                    times.extend_from_slice(&p[r].times);
                }
                merge_result(&times, self.t_max).winner
            })
            .collect();
        // phase 2: scatter the gated update, each shard gated by its
        // slice of the global rule — winner column 1, globally silent
        // row all-1 (the search term), 0 otherwise
        let calls: Vec<ShardCall> = shards
            .iter()
            .enumerate()
            .zip(scatter_payloads(chunk, k))
            .map(|((i, s), payload)| {
                let range = self.plan.range(i);
                let cl = range.len();
                let mut gates = vec![0f32; rows * cl];
                for (r, winner) in winners.iter().enumerate() {
                    match winner {
                        None => gates[r * cl..(r + 1) * cl].fill(1.0),
                        Some(w) if range.contains(w) => gates[r * cl + (w - range.start)] = 1.0,
                        Some(_) => {}
                    }
                }
                s.begin_learn_gated(payload, gates)
            })
            .collect::<Result<Vec<_>>>()?;
        let mut parts = Vec::with_capacity(k);
        for call in calls {
            parts.push(call.wait_all()?);
        }
        Ok((0..rows)
            .map(|r| {
                let mut times = Vec::with_capacity(self.c);
                for p in &parts {
                    times.extend_from_slice(&p[r].times);
                }
                merge_result(&times, self.t_max)
            })
            .collect())
    }

    fn count_request(&self, sparse: u64, dense: u64) {
        self.metrics.incr("requests", sparse + dense);
        if sparse > 0 {
            self.metrics.incr("requests_sparse", sparse);
        }
        if dense > 0 {
            self.metrics.incr("requests_dense", dense);
        }
    }

    /// Merge per-shard result vectors into one result per volley:
    /// concatenate times in shard order, re-select the winner globally.
    /// A shard error for a volley errors that volley (first shard's
    /// error wins, matching "first error aborts in kind").
    fn gather(&self, parts: Vec<Vec<Result<VolleyResult>>>) -> Vec<Result<VolleyResult>> {
        let nvol = parts.first().map_or(0, |p| p.len());
        let mut iters: Vec<_> = parts.into_iter().map(IntoIterator::into_iter).collect();
        (0..nvol)
            .map(|_| {
                let mut times = Vec::with_capacity(self.c);
                let mut err: Option<Error> = None;
                for it in &mut iters {
                    match it.next().expect("every shard answers every volley") {
                        Ok(r) => times.extend_from_slice(&r.times),
                        Err(e) => {
                            err.get_or_insert(e);
                        }
                    }
                }
                match err {
                    Some(e) => Err(e),
                    None => Ok(merge_result(&times, self.t_max)),
                }
            })
            .collect()
    }

    /// The full `[c, n]` weight matrix, shard rows concatenated in
    /// plan order — read under the shared lock, so the snapshot is one
    /// consistent generation even while learns are in flight.
    pub fn weights(&self) -> Result<Tensor> {
        let shards = self.shards.read().unwrap();
        self.weights_locked(&shards)
    }

    /// The concatenation itself (callers already holding a lock side).
    fn weights_locked(&self, shards: &[Arc<dyn ShardTransport>]) -> Result<Tensor> {
        let mut data = Vec::with_capacity(self.c * self.n);
        for s in shards {
            data.extend_from_slice(&s.weights()?.data);
        }
        Tensor::new(vec![self.c, self.n], data)
    }

    /// Scatter a full `[c, n]` weight matrix across the shards (the
    /// in-process restore path). Exclusive against learns and infers.
    pub fn set_weights(&self, w: Tensor) -> Result<()> {
        if w.shape != vec![self.c, self.n] {
            return Err(Error::Runtime(format!(
                "weights shape {:?} != [{}, {}]",
                w.shape, self.c, self.n
            )));
        }
        let shards = self.shards.write().unwrap();
        for (i, s) in shards.iter().enumerate() {
            let r = self.plan.range(i);
            let slice = Tensor::new(
                vec![r.len(), self.n],
                w.data[r.start * self.n..r.end * self.n].to_vec(),
            )?;
            s.set_weights(slice)?;
        }
        Ok(())
    }

    /// Persist as K per-shard `CWKP` files plus the `CWKS` manifest at
    /// `path` tying them together. Shard files are **content-addressed**
    /// (`<name>.shard<i>.<crc>.ckpt`, [`manifest::shard_path`]) and
    /// written first, so a new generation never overwrites the old
    /// one's bytes; the manifest rename is the single atomic commit —
    /// a crash anywhere mid-save leaves the old manifest pointing at
    /// the complete old set, exactly the old-or-new guarantee the
    /// single-file `CWKP` save gives. Superseded generations are swept
    /// best-effort after the commit. The whole save runs under the
    /// shared lock: an autosave racing a learn must persist one weight
    /// generation, never a mix whose fresh CRCs would defeat the
    /// loader's mixed-generation gate.
    ///
    /// A remote model then pushes the committed generation to each
    /// standby host ([`crate::dist::replicate`]), best-effort: a
    /// follower that cannot be reached costs a `replication_errors`
    /// count, not the save — the local commit already succeeded.
    pub fn save_checkpoints(&self, path: &Path) -> Result<()> {
        let ctx = crate::obs::current();
        let t_ckpt = ctx.sampled.then(Instant::now);
        {
            let shards = self.shards.read().unwrap();
            let mut entries = Vec::with_capacity(self.plan.k);
            for (i, s) in shards.iter().enumerate() {
                let range = self.plan.range(i);
                let bytes = Checkpoint {
                    n: self.n as u32,
                    c: range.len() as u32,
                    t_max: self.t_max as u32,
                    theta: self.theta,
                    seed: self.seed,
                    weights: s.weights()?.data,
                }
                .to_bytes()?;
                let crc = crc32(&bytes);
                write_atomic(&shard_path(path, i, crc), &bytes)?;
                entries.push(ShardEntry {
                    start: range.start as u32,
                    end: range.end as u32,
                    file_crc: crc,
                });
            }
            let m = ShardManifest {
                n: self.n as u32,
                c: self.c as u32,
                t_max: self.t_max as u32,
                theta: self.theta,
                seed: self.seed,
                shards: entries,
            };
            m.save(path)?;
            manifest::sweep_stale_shards(path, &m);
        }
        let generation = self.generation.fetch_add(1, Ordering::AcqRel) + 1;
        if let Some(t) = t_ckpt {
            crate::obs::record(
                ctx,
                crate::obs::Stage::Checkpoint,
                self.plan.k as u32,
                t,
                t.elapsed(),
            );
        }
        // replication runs outside the lock — the generation is
        // committed locally; followers catch up without blocking
        // serving traffic
        if let Some(remote) = &self.remote {
            let followers = remote.standbys.lock().unwrap().clone();
            for (i, host) in followers.iter().enumerate() {
                let t_rep = ctx.sampled.then(Instant::now);
                let res = replicate(host, &remote.client, &remote.retry, &remote.name, path);
                if let Some(t) = t_rep {
                    let flags = if res.is_err() { crate::obs::SPAN_ERROR } else { 0 };
                    crate::obs::record_flagged(
                        ctx,
                        crate::obs::Stage::Replicate,
                        flags,
                        i as u32,
                        t,
                        t.elapsed(),
                    );
                }
                match res {
                    Ok(()) => {
                        self.metrics.incr("replications", 1);
                        remote
                            .replicated
                            .lock()
                            .unwrap()
                            .insert(host.clone(), generation);
                    }
                    Err(e) => {
                        self.metrics.incr("replication_errors", 1);
                        eprintln!("replication to {host} failed: {e}");
                    }
                }
            }
            // gauge, not counter: how many committed generations the
            // most-behind standby is missing right now (0 with no
            // standbys left — nothing is waiting on replication)
            let replicated = remote.replicated.lock().unwrap();
            let lag = followers
                .iter()
                .map(|h| generation.saturating_sub(*replicated.get(h).unwrap_or(&0)))
                .max()
                .unwrap_or(0);
            self.metrics.set("replication_lag_generations", lag);
        }
        Ok(())
    }

    /// Read and fully verify a `CWKS` generation at `path` without
    /// touching any engine: manifest CRC, shard count and ranges
    /// against this model's plan, every shard file's bytes against the
    /// manifest's CRC record, every slice's geometry. The verification
    /// half of [`ShardedModel::load_checkpoints`], shared with
    /// [`ShardedModel::failover`] — both must reject a partial,
    /// corrupt or foreign generation as a unit.
    fn verified_slices(&self, path: &Path) -> Result<Vec<Tensor>> {
        let m = ShardManifest::read(path)?;
        if (m.n as usize, m.c as usize) != (self.n, self.c) {
            return Err(Error::Checkpoint(format!(
                "shard manifest is [{}, {}], model wants [{}, {}]",
                m.c, m.n, self.c, self.n
            )));
        }
        if m.shards.len() != self.plan.k {
            return Err(Error::Checkpoint(format!(
                "shard manifest has {} shards, model is sharded {} ways",
                m.shards.len(),
                self.plan.k
            )));
        }
        let mut slices = Vec::with_capacity(self.plan.k);
        for (i, entry) in m.shards.iter().enumerate() {
            let range = self.plan.range(i);
            if (entry.start as usize, entry.end as usize) != (range.start, range.end) {
                return Err(Error::Checkpoint(format!(
                    "shard {i} covers {}..{} in the manifest, {}..{} in the plan",
                    entry.start, entry.end, range.start, range.end
                )));
            }
            let spath = shard_path(path, i, entry.file_crc);
            let bytes = std::fs::read(&spath)
                .map_err(|e| Error::Checkpoint(format!("read {}: {e}", spath.display())))?;
            // the name is derived from the manifest's CRC, but the
            // bytes must still hash to it — a renamed or tampered file
            // is rejected before any engine is touched
            if crc32(&bytes) != entry.file_crc {
                return Err(Error::Checkpoint(format!(
                    "{} does not match its shard manifest (mixed save generations?)",
                    spath.display()
                )));
            }
            let ckpt = Checkpoint::from_bytes(&bytes)
                .map_err(|e| Error::Checkpoint(format!("{}: {e}", spath.display())))?;
            if (ckpt.n as usize, ckpt.c as usize) != (self.n, range.len()) {
                return Err(Error::Checkpoint(format!(
                    "{} is [{}, {}], shard {i} wants [{}, {}]",
                    spath.display(),
                    ckpt.c,
                    ckpt.n,
                    range.len(),
                    self.n
                )));
            }
            slices.push(Tensor::new(vec![range.len(), self.n], ckpt.weights)?);
        }
        Ok(slices)
    }

    /// Restore from a `CWKS` manifest at `path`: every shard file is
    /// read and verified (manifest CRC, per-file CRC against the
    /// manifest's record, geometry against this model's plan) **before**
    /// any engine is touched — missing, truncated, corrupt or
    /// foreign-save shard files reject the load as a unit and the old
    /// weights keep serving.
    pub fn load_checkpoints(&self, path: &Path) -> Result<()> {
        let slices = self.verified_slices(path)?;
        // everything verified; swap exclusively — no infer, learn or
        // save may observe the matrix half-replaced
        let shards = self.shards.write().unwrap();
        for (s, w) in shards.iter().zip(slices) {
            s.set_weights(w)?;
        }
        Ok(())
    }

    /// Replace every failed shard's transport with a standby host
    /// resumed from the committed generation at `ckpt_path` (the
    /// replicated `CWKS` manifest), then roll **all** shards back to
    /// that generation — learns applied after the last committed save
    /// are lost, exactly crash-restart semantics, and exactly why the
    /// chaos contract is "old weights never regress *past a commit*".
    ///
    /// The swap is gated hard on the replica: the standby is
    /// provisioned (it resumes from its own replicated slice), its
    /// resumed weights are fetched and must match the committed slice
    /// **bit for bit** — a standby that never got the generation, or
    /// got a corrupted one, is a typed error, not a silent divergence.
    ///
    /// Runs under the exclusive lock; requests in the window keep
    /// getting the failed transport's typed errors, never hangs.
    /// Returns how many shards were failed over (0 = nothing failed).
    pub fn failover(&self, ckpt_path: &Path) -> Result<usize> {
        let remote = self.remote.as_ref().ok_or_else(|| {
            Error::Coordinator(
                "failover needs a remote sharded model (in-process shards have no standby hosts)"
                    .into(),
            )
        })?;
        let slices = self.verified_slices(ckpt_path)?;
        let mut shards = self.shards.write().unwrap();
        let failed: Vec<usize> = shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.failed())
            .map(|(i, _)| i)
            .collect();
        if failed.is_empty() {
            return Ok(0);
        }
        for &i in &failed {
            let host = remote.standbys.lock().unwrap().pop().ok_or_else(|| {
                Error::Coordinator(format!("no standby host left to take over shard {i}"))
            })?;
            let t = TcpShard::open(
                &host,
                &remote.name,
                i,
                self.plan.range(i),
                self.n,
                self.t_max,
                self.theta,
                self.seed,
                &remote.client,
                &remote.retry,
            )?;
            let resumed = t.weights()?;
            let committed = &slices[i];
            let bit_match = resumed.data.len() == committed.data.len()
                && resumed
                    .data
                    .iter()
                    .zip(&committed.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            if !bit_match {
                return Err(Error::Checkpoint(format!(
                    "standby {host} resumed shard {i} with weights that do not match \
                     the committed generation"
                )));
            }
            shards[i].shutdown();
            shards[i] = Arc::new(t);
            self.metrics.incr("failovers", 1);
        }
        // roll the surviving shards back to the committed generation
        // too — the model must serve one generation, not a mix of
        // committed (standby) and post-commit (survivor) weights
        for (s, w) in shards.iter().zip(slices) {
            s.set_weights(w)?;
        }
        Ok(failed.len())
    }

    /// Drain for unload: flag the model stopped (learns bypass the
    /// batchers, so they check it under the state lock and fail typed),
    /// shut the shard transports down (queued work flushes, later
    /// submitters get typed errors), then wait out whatever holds the
    /// state lock — after this returns, nothing mutates the model
    /// again.
    pub fn drain(&self) {
        self.stopped.store(true, Ordering::Release);
        for s in self.shards.read().unwrap().iter() {
            s.shutdown();
        }
        drop(self.shards.write().unwrap());
    }

    /// Chaos-harness fault: shut down one shard's transport while the
    /// rest of the model keeps running. Queued work on that shard
    /// flushes, later infers that scatter onto it gather a typed
    /// error — so a killed shard degrades the model to typed errors,
    /// never to hangs or silent drops (the contract
    /// `qos::replay::chaos_run` asserts). The model-level state lock
    /// and the other shards are untouched; recovery is
    /// [`ShardedModel::failover`] (remote) or unloading the slot.
    pub fn kill_shard(&self, i: usize) {
        self.shards.read().unwrap()[i].shutdown();
    }
}

/// Concatenated per-column times → one [`VolleyResult`] with the
/// global WTA winner: the earliest time wins, ties break to the lowest
/// column index, an all-silent row has no winner — the exact scan
/// `runtime::plan::KernelPlan::wta` performs on the unsharded matrix.
pub fn merge_result(times: &[f32], t_max: usize) -> VolleyResult {
    let mut best = 0usize;
    for (i, &t) in times.iter().enumerate() {
        if t < times[best] {
            best = i;
        }
    }
    let winner = (!times.is_empty() && times[best] < t_max as f32).then_some(best);
    VolleyResult {
        times: times.to_vec(),
        winner,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_partitions_contiguously() {
        for (c, k) in [(8, 1), (8, 8), (8, 3), (16, 4), (16, 5), (12, 7)] {
            let plan = ShardPlan::new(c, k).unwrap();
            let ranges = plan.ranges();
            assert_eq!(ranges.len(), k);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges[k - 1].end, c);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous ({c}, {k})");
            }
            let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "balanced ({c}, {k}): {sizes:?}");
            assert!(sizes.iter().all(|&s| s >= 1));
        }
    }

    #[test]
    fn plan_rejects_degenerate_counts() {
        assert!(ShardPlan::new(8, 0).is_err());
        assert!(ShardPlan::new(8, 9).is_err());
        assert!(ShardPlan::new(0, 1).is_err());
    }

    #[test]
    fn merge_result_matches_wta_semantics() {
        let r = merge_result(&[5.0, 2.0, 9.0], 16);
        assert_eq!(r.winner, Some(1));
        // tie -> lowest index
        let r = merge_result(&[3.0, 3.0, 16.0], 16);
        assert_eq!(r.winner, Some(0));
        // all silent -> no winner
        let r = merge_result(&[16.0, 16.0], 16);
        assert_eq!(r.winner, None);
        assert_eq!(r.times, vec![16.0, 16.0]);
        assert_eq!(merge_result(&[], 16).winner, None);
    }
}
