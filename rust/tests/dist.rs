//! Distributed shard tier end-to-end: remote shards over the
//! [`catwalk::dist::ShardTransport`] seam vs the in-process and
//! unsharded baselines (the bit-identity acceptance gate), checkpoint
//! replication to follower hosts, standby failover after a killed
//! shard host, the reconnect retry schedule, the global connection cap
//! on both codecs, and the v3-only learn-gates surface.

use catwalk::coordinator::{BatcherConfig, TnnHandle};
use catwalk::dist::{connect_backoff, replicate, retry_with, RetryPolicy};
use catwalk::error::Error;
use catwalk::proto::frame;
use catwalk::proto::{AdminReply, ModelCmd, Outcome, Request};
use catwalk::qos::replay::{boot_shard_host, ShardHost};
use catwalk::qos::QosConfig;
use catwalk::registry::checkpoint::Checkpoint;
use catwalk::registry::{ModelRegistry, RegistryConfig};
use catwalk::rng::Xoshiro256;
use catwalk::runtime::BackendKind;
use catwalk::server::{ClientConfig, FramedClient, Server};
use catwalk::shard::manifest::ShardManifest;
use catwalk::shard::ShardedModel;
use catwalk::volley::VolleyResult;
use catwalk::SpikeVolley;
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn native_env() -> bool {
    matches!(BackendKind::from_env(), Ok(BackendKind::Native))
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("catwalk-dist-e2e-{tag}-{}", std::process::id()))
}

/// Short socket timeouts so a regression toward hanging fails the
/// suite quickly instead of wedging it.
fn client_cfg() -> ClientConfig {
    ClientConfig {
        read_timeout: Some(Duration::from_secs(5)),
        write_timeout: Some(Duration::from_secs(5)),
        ..ClientConfig::default()
    }
}

/// A tight schedule: tests should not sleep out a production backoff.
fn retry_cfg() -> RetryPolicy {
    RetryPolicy {
        attempts: 3,
        base: Duration::from_millis(5),
        max: Duration::from_millis(20),
        jitter: 0.2,
        seed: 7,
    }
}

fn boot_host(dir: &PathBuf, tag: &str) -> ShardHost {
    boot_shard_host(
        std::path::Path::new("/no-such-dir"),
        &dir.join(tag),
        QosConfig::default(),
    )
    .unwrap()
}

fn random_volleys(rng: &mut Xoshiro256, rows: usize, n: usize, density: f64) -> Vec<SpikeVolley> {
    (0..rows)
        .map(|_| {
            SpikeVolley::dense(
                (0..n)
                    .map(|_| {
                        if rng.gen_bool(density) {
                            (rng.gen_f64() * 8.0) as f32
                        } else {
                            16.0
                        }
                    })
                    .collect(),
            )
        })
        .collect()
}

fn result_bits(r: &VolleyResult) -> (Option<usize>, Vec<u32>) {
    (r.winner, r.times.iter().map(|t| t.to_bits()).collect())
}

fn unwrap_bits(rs: Vec<catwalk::Result<VolleyResult>>) -> Vec<(Option<usize>, Vec<u32>)> {
    rs.into_iter().map(|r| result_bits(&r.unwrap())).collect()
}

// ------------------------------------------------------ retry schedule

/// The reconnect schedule is pinned by an injected clock: no wall-time
/// sleeps, the exact jittered delays, bounded attempts — and
/// [`connect_backoff`] against a dead address surfaces the last typed
/// connect error after exactly `attempts` tries.
#[test]
fn retry_schedule_is_deterministic_under_injected_clock() {
    let p = retry_cfg();
    assert_eq!(p.delays(), p.delays(), "schedule is a pure function of the policy");
    assert_eq!(p.delays().len(), (p.attempts - 1) as usize);

    let mut slept: Vec<Duration> = Vec::new();
    let mut attempts_seen = Vec::new();
    let r: catwalk::Result<()> = retry_with(
        &p,
        |d| slept.push(d),
        |attempt| {
            attempts_seen.push(attempt);
            Err(Error::Coordinator("host still down".into()))
        },
    );
    assert!(r.is_err());
    assert_eq!(attempts_seen, vec![0, 1, 2]);
    assert_eq!(slept, p.delays(), "every sleep is exactly the scheduled delay");

    // success mid-schedule stops both the calls and the sleeps
    let mut slept = Vec::new();
    let ok = retry_with(&p, |d| slept.push(d), |a| {
        if a == 1 {
            Ok("up")
        } else {
            Err(Error::Coordinator("not yet".into()))
        }
    });
    assert_eq!(ok.unwrap(), "up");
    assert_eq!(slept, p.delays()[..1].to_vec());

    // a dead address: typed error, never a hang (the real sleeps here
    // total ~15ms under the tight test policy)
    let err = connect_backoff("127.0.0.1:1", &client_cfg(), &p).unwrap_err();
    let msg = err.to_string();
    assert!(!msg.is_empty());
}

// ------------------------------- bit-identity acceptance gate (remote)

/// The tentpole contract: a model whose shards live on remote
/// `repro serve --standby` hosts answers infer and multi-step learn
/// **bit-identically** to the in-process sharded model and the
/// unsharded engine, and its framed response bytes are byte-identical
/// too. Save/restart/resume round-trips through the `CWKS` generation.
#[test]
fn remote_shards_match_in_process_and_unsharded_bitwise() {
    if !native_env() {
        return;
    }
    let scratch = temp_dir("bitident");
    let _ = std::fs::remove_dir_all(&scratch);
    let host_a = boot_host(&scratch, "host-a");
    let host_b = boot_host(&scratch, "host-b");
    let hosts = vec![host_a.addr.clone(), host_b.addr.clone()];

    let (n, theta, seed) = (16usize, 6.0f32, 11u64);
    let remote = ShardedModel::open_remote(
        "/no-such-dir",
        "dist",
        n,
        theta,
        seed,
        &hosts,
        Vec::new(),
        client_cfg(),
        retry_cfg(),
        BatcherConfig::default(),
    )
    .unwrap();
    let local =
        ShardedModel::open("/no-such-dir", n, theta, seed, 2, BatcherConfig::default()).unwrap();
    let solo = TnnHandle::open("/no-such-dir", n, theta, seed).unwrap();

    let mut rng = Xoshiro256::new(77);

    // infer: all three produce the same bits, volley for volley
    let vols = random_volleys(&mut rng, 10, n, 0.3);
    let got_remote = unwrap_bits(remote.infer(vols.clone(), None));
    let got_local = unwrap_bits(local.infer(vols.clone(), None));
    let got_solo: Vec<_> = solo
        .infer(vols.clone())
        .unwrap()
        .iter()
        .map(result_bits)
        .collect();
    assert_eq!(got_remote, got_local, "remote infer == in-process infer");
    assert_eq!(got_remote, got_solo, "remote infer == unsharded infer");

    // ...and the *wire bytes* agree, not just the decoded structs
    let to_frame = |bits: &[(Option<usize>, Vec<u32>)]| {
        let rs: Vec<VolleyResult> = bits
            .iter()
            .map(|(w, t)| VolleyResult {
                winner: *w,
                times: t.iter().map(|b| f32::from_bits(*b)).collect(),
            })
            .collect();
        frame::encode_response(&catwalk::proto::Response {
            id: 42,
            outcome: Outcome::Results(rs),
        })
        .unwrap()
    };
    assert_eq!(
        to_frame(&got_remote),
        to_frame(&got_solo),
        "framed response payloads are byte-identical"
    );

    // multi-step learn: three rounds of the two-phase gated protocol,
    // every returned result and the full weight matrix bit-identical
    for round in 0..3 {
        let lv = random_volleys(&mut rng, 6 + round, n, 0.25);
        let lr = unwrap_bits(remote.learn(lv.clone(), None));
        let ll = unwrap_bits(local.learn(lv.clone(), None));
        let ls: Vec<_> = solo.learn(lv).unwrap().iter().map(result_bits).collect();
        assert_eq!(lr, ll, "learn round {round}: remote == in-process");
        assert_eq!(lr, ls, "learn round {round}: remote == unsharded");
    }
    let wbits = |t: &catwalk::runtime::Tensor| -> Vec<u32> {
        t.data.iter().map(|w| w.to_bits()).collect()
    };
    let learned = wbits(&remote.weights().unwrap());
    assert_eq!(learned, wbits(&local.weights().unwrap()));
    assert_eq!(learned, wbits(&solo.weights().unwrap()));

    // save/restart/resume: the remote model's CWKS generation restores
    // a fresh in-process model to the same bits, and infers after the
    // resume still agree
    let coord = scratch.join("coord");
    std::fs::create_dir_all(&coord).unwrap();
    let ckpt = coord.join("dist.ckpt");
    remote.save_checkpoints(&ckpt).unwrap();
    let resumed =
        ShardedModel::open("/no-such-dir", n, theta, seed, 2, BatcherConfig::default()).unwrap();
    resumed.load_checkpoints(&ckpt).unwrap();
    assert_eq!(learned, wbits(&resumed.weights().unwrap()), "resume is bit-exact");
    let post = random_volleys(&mut rng, 4, n, 0.4);
    assert_eq!(
        unwrap_bits(remote.infer(post.clone(), None)),
        unwrap_bits(resumed.infer(post, None)),
        "post-resume infers agree"
    );

    drop(remote);
    host_a.shutdown();
    host_b.shutdown();
    let _ = std::fs::remove_dir_all(&scratch);
}

// ------------------------------------------------ replication (follower)

/// A committed `CWKS` generation pushed with [`replicate`] is servable
/// on the follower: provisioning the slices there resumes them from
/// the replicated files, bit-identical to the coordinator's weights.
#[test]
fn replicate_pushes_generation_follower_resumes_it() {
    if !native_env() {
        return;
    }
    let scratch = temp_dir("replicate");
    let _ = std::fs::remove_dir_all(&scratch);
    let follower = boot_host(&scratch, "follower");

    let (n, theta, seed) = (16usize, 6.0f32, 3u64);
    let model =
        ShardedModel::open("/no-such-dir", n, theta, seed, 2, BatcherConfig::default()).unwrap();
    let mut rng = Xoshiro256::new(5);
    for _ in 0..3 {
        for r in model.learn(random_volleys(&mut rng, 8, n, 0.3), None) {
            r.unwrap();
        }
    }
    let coord = scratch.join("coord");
    std::fs::create_dir_all(&coord).unwrap();
    let ckpt = coord.join("rep.ckpt");
    model.save_checkpoints(&ckpt).unwrap();

    replicate(&follower.addr, &client_cfg(), &retry_cfg(), "rep", &ckpt).unwrap();

    // provision each slice on the follower: it must resume from the
    // replicated generation, and FetchCkpt must return the same bits
    // the coordinator saved
    let manifest = ShardManifest::read(&ckpt).unwrap();
    let full = model.weights().unwrap();
    let mut client = FramedClient::connect_with(&follower.addr, &client_cfg()).unwrap();
    for (i, entry) in manifest.shards.iter().enumerate() {
        let reply = client
            .call_admin(ModelCmd::CreateColumns {
                name: "rep".into(),
                index: i,
                n,
                theta,
                seed,
                start: entry.start as usize,
                end: entry.end as usize,
            })
            .unwrap();
        assert!(matches!(reply, AdminReply::Models(ref ms) if ms.len() == 1));
        let bytes = match client
            .call_admin(ModelCmd::FetchCkpt { name: format!("rep-s{i}") })
            .unwrap()
        {
            AdminReply::Ckpt(b) => b,
            other => panic!("expected checkpoint bytes, got {other:?}"),
        };
        let slice = Checkpoint::from_bytes(&bytes).unwrap();
        let want: Vec<u32> = full.data
            [entry.start as usize * n..entry.end as usize * n]
            .iter()
            .map(|w| w.to_bits())
            .collect();
        let got: Vec<u32> = slice.weights.iter().map(|w| w.to_bits()).collect();
        assert_eq!(got, want, "follower shard {i} resumed the committed bits");
    }
    let _ = client.quit();
    follower.shutdown();
    let _ = std::fs::remove_dir_all(&scratch);
}

// ---------------------------------------------------- standby failover

/// Kill a shard host mid-traffic: requests in the window answer typed
/// errors (never hang), [`ShardedModel::failover`] re-opens the dead
/// shard's column slice on the standby from the replicated generation,
/// and the whole model rolls back to the committed bits.
#[test]
fn killed_shard_host_fails_over_to_standby() {
    if !native_env() {
        return;
    }
    let scratch = temp_dir("failover");
    let _ = std::fs::remove_dir_all(&scratch);
    let host_a = boot_host(&scratch, "host-a");
    let host_b = boot_host(&scratch, "host-b");
    let standby = boot_host(&scratch, "standby");

    let (n, theta, seed) = (16usize, 6.0f32, 21u64);
    let model = ShardedModel::open_remote(
        "/no-such-dir",
        "fo",
        n,
        theta,
        seed,
        &[host_a.addr.clone(), host_b.addr.clone()],
        vec![standby.addr.clone()],
        client_cfg(),
        retry_cfg(),
        BatcherConfig::default(),
    )
    .unwrap();

    let mut rng = Xoshiro256::new(13);
    for _ in 0..3 {
        for r in model.learn(random_volleys(&mut rng, 8, n, 0.3), None) {
            r.unwrap();
        }
    }
    // the save commits locally and replicates to the standby
    let coord = scratch.join("coord");
    std::fs::create_dir_all(&coord).unwrap();
    let ckpt = coord.join("fo.ckpt");
    model.save_checkpoints(&ckpt).unwrap();
    let committed: Vec<u32> = model
        .weights()
        .unwrap()
        .data
        .iter()
        .map(|w| w.to_bits())
        .collect();

    // learns past the commit will be rolled back by the failover —
    // crash-restart semantics
    for r in model.learn(random_volleys(&mut rng, 4, n, 0.3), None) {
        r.unwrap();
    }

    host_b.kill();
    // drive failure detection: every probe in the window must answer
    // (typed error or success), never hang
    let mut probes = 0;
    while model.failed_shards().is_empty() && probes < 200 {
        let t0 = std::time::Instant::now();
        for _ in model.infer(random_volleys(&mut rng, 1, n, 0.5), None) {
            // Ok before the worker notices, Err after — both are fine;
            // what is not fine is blocking
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "probe hung during the kill window"
        );
        probes += 1;
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(model.failed_shards(), vec![1], "shard 1's host is down");
    // a request against the failed shard is a typed error immediately
    let latched = model.infer(random_volleys(&mut rng, 1, n, 0.5), None);
    assert!(latched.iter().any(|r| r.is_err()), "failed shard answers typed");

    assert_eq!(model.failover(&ckpt).unwrap(), 1, "one shard failed over");
    assert!(model.failed_shards().is_empty(), "standby took the slice");
    let after: Vec<u32> = model
        .weights()
        .unwrap()
        .data
        .iter()
        .map(|w| w.to_bits())
        .collect();
    assert_eq!(after, committed, "failover rolls back to the committed bits");
    for r in model.infer(random_volleys(&mut rng, 4, n, 0.4), None) {
        r.unwrap();
    }
    // with the standby pool drained, a second failure is a typed error
    model.kill_shard(0);
    assert!(model.failover(&ckpt).is_err(), "no standby left: typed refusal");

    drop(model);
    host_a.shutdown();
    host_b.shutdown();
    standby.shutdown();
    let _ = std::fs::remove_dir_all(&scratch);
}

// ----------------------------------------------------- connection cap

/// `--max-conns N`: over-cap connections get a first-class BUSY on the
/// framed codec and a `BUSY <ms>` line on the text codec — never a
/// silent close — and each refusal counts in `connections_refused`.
#[test]
fn max_conns_refuses_busy_on_both_codecs_and_counts() {
    let scratch = temp_dir("maxconns");
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch).unwrap();
    let registry = Arc::new(ModelRegistry::standby(RegistryConfig {
        artifacts_dir: PathBuf::from("/no-such-dir"),
        ..RegistryConfig::default()
    }));
    let server = Server::with_registry(registry).with_max_conns(1);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let join = std::thread::spawn(move || server.serve("127.0.0.1:0", |p| tx.send(p).unwrap()));
    let addr = format!("127.0.0.1:{}", rx.recv().unwrap());

    // the first connection occupies the only slot
    let mut held = FramedClient::connect_with(&addr, &client_cfg()).unwrap();

    // framed over-cap connect: the handshake is answered with the
    // degraded BUSY error-form (no version negotiated yet), which the
    // client surfaces as a typed connect error
    let refused = FramedClient::connect_with(&addr, &client_cfg()).unwrap_err();
    assert!(
        refused.to_string().contains("busy"),
        "framed refusal is the BUSY shape, got: {refused}"
    );

    // text over-cap connect: the same first-class BUSY line the QoS
    // shed uses
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut w = stream.try_clone().unwrap();
    w.write_all(b"PING\n").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert!(line.starts_with("BUSY "), "text refusal line, got: {line:?}");
    let hint: u32 = line.trim().strip_prefix("BUSY ").unwrap().parse().unwrap();
    assert!(hint > 0, "retry hint is a positive ms count");

    // both refusals are counted on the held connection's STATS view
    let mut refused_count = 0;
    for _ in 0..50 {
        refused_count = *held
            .stats()
            .unwrap()
            .counters
            .get("connections_refused")
            .unwrap_or(&0);
        if refused_count >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(refused_count, 2, "each over-cap connection counts once");

    // freeing the slot readmits new connections
    let _ = held.quit();
    drop(held);
    let mut ok = None;
    for _ in 0..100 {
        match FramedClient::connect_with(&addr, &client_cfg()) {
            Ok(c) => {
                ok = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    let mut c = ok.expect("slot frees after the held connection quits");
    let _ = c.quit();

    stop.store(true, std::sync::atomic::Ordering::Release);
    join.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&scratch);
}

// -------------------------------------------------- gates wire surface

/// Learn gates are a v3-only construct and only LEARN may carry them:
/// a v2-negotiated connection sending the gates flag gets a typed
/// refusal (the negotiated version is a contract), and a gated learn
/// addressed at a *sharded* slot is refused too — gate derivation is
/// the coordinator's job, only a single-engine column slice applies
/// caller-supplied gates.
#[test]
fn gates_are_v3_only_and_single_engine_only() {
    if !native_env() {
        return;
    }
    let scratch = temp_dir("gates");
    let _ = std::fs::remove_dir_all(&scratch);
    let host = boot_host(&scratch, "host");

    let (n, theta, seed) = (16usize, 6.0f32, 9u64);
    let mut client = FramedClient::connect_with(&host.addr, &client_cfg()).unwrap();
    // provision a column slice 0..4 as slot g-s0
    let reply = client
        .call_admin(ModelCmd::CreateColumns {
            name: "g".into(),
            index: 0,
            n,
            theta,
            seed,
            start: 0,
            end: 4,
        })
        .unwrap();
    assert!(matches!(reply, AdminReply::Models(ref ms) if ms.len() == 1 && ms[0].c == 4));

    // a gated learn against the column slot applies exactly the gates
    let volley = SpikeVolley::dense(vec![1.0; n]);
    let rs = client
        .learn_gated("g-s0", vec![volley.clone()], vec![1.0, 0.0, 0.0, 0.0])
        .unwrap();
    assert_eq!(rs.len(), 1);

    // a wrong-length gate vector is a typed error, not a crash
    let resp = client
        .call(
            Request::learn(vec![volley.clone()])
                .with_model("g-s0")
                .with_gates(vec![1.0]),
        )
        .unwrap();
    assert!(
        matches!(resp.outcome, Outcome::Error(ref m) if m.contains("gates length")),
        "got {:?}",
        resp.outcome
    );
    let _ = client.quit();
    host.shutdown();

    // a sharded slot refuses gates outright: its gate derivation is
    // the coordinator's job
    let registry = Arc::new(
        ModelRegistry::open_sharded(
            RegistryConfig {
                artifacts_dir: PathBuf::from("/no-such-dir"),
                ..RegistryConfig::default()
            },
            "default",
            catwalk::registry::ModelSpec {
                n,
                theta,
                seed,
            },
            2,
        )
        .unwrap(),
    );
    let server = Server::with_registry(registry).with_max_conns(0);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let join = std::thread::spawn(move || server.serve("127.0.0.1:0", |p| tx.send(p).unwrap()));
    let addr = format!("127.0.0.1:{}", rx.recv().unwrap());
    let mut client = FramedClient::connect_with(&addr, &client_cfg()).unwrap();
    let c = client.c;
    let err = client
        .learn_gated("default", vec![volley.clone()], vec![0.0; c])
        .unwrap_err();
    assert!(
        err.to_string().contains("sharded"),
        "sharded slot refuses caller-supplied gates, got: {err}"
    );

    // v2 handshake, then a gated LEARN frame: the server rejects the
    // v3 construct on the v2-negotiated connection with a typed error
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    frame::write_frame(&mut writer, frame::FrameType::Hello, &frame::encode_hello(2, 2)).unwrap();
    writer.flush().unwrap();
    let (ty, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert!(matches!(ty, frame::FrameType::Ack));
    assert_eq!(frame::decode_ack(&payload).unwrap().version, 2);
    let gated = Request::learn(vec![volley]).with_gates(vec![0.0; c]);
    let gated = Request { id: 1, ..gated };
    frame::write_frame(
        &mut writer,
        frame::FrameType::Request,
        &frame::encode_request(&gated).unwrap(),
    )
    .unwrap();
    writer.flush().unwrap();
    let (ty, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert!(matches!(ty, frame::FrameType::Response));
    let resp = frame::decode_response(&payload).unwrap();
    assert!(
        matches!(resp.outcome, Outcome::Error(ref m) if m.contains("v3")),
        "v2 connection carrying gates is refused, got {:?}",
        resp.outcome
    );

    let _ = client.quit();
    stop.store(true, std::sync::atomic::Ordering::Release);
    join.join().unwrap().unwrap();
    let _ = std::fs::remove_dir_all(&scratch);
}
