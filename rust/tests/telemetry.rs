//! Telemetry-plane end-to-end gates (DESIGN.md §2.9): the bit-identity
//! invariant (sampler + HTTP exporter + tracing all on vs all off
//! leaves every serving reply **byte** identical on all three codecs,
//! for single, sharded, and remote-shard models), the `/metrics`
//! endpoint parsing under the pinned Prometheus exposition grammar
//! with nonzero windowed rates after replayed load, the health model
//! flipping `/readyz` to Degraded with a typed reason when a remote
//! shard host dies, the `CMD_FETCH_METRICS` / `CMD_FETCH_HEALTH`
//! admin surface plus its v2 typed refusal, and the additive STATS
//! identity rows (`uptime_secs`, `start_epoch_secs`, `proto_version`).
//!
//! Every test here touches the process-global tracer (the bit-identity
//! run arms it), so they serialize on one mutex like `obs.rs` does.

use catwalk::dist::RetryPolicy;
use catwalk::obs;
use catwalk::obs::telemetry::{self, HealthState, TelemetryOptions};
use catwalk::proto::frame::{self, FrameType};
use catwalk::proto::{ModelCmd, Outcome, Request};
use catwalk::qos::replay::{boot_shard_host, ShardHost};
use catwalk::qos::QosConfig;
use catwalk::registry::{ModelRegistry, ModelSpec, RegistryConfig};
use catwalk::rng::Xoshiro256;
use catwalk::runtime::BackendKind;
use catwalk::server::{ClientConfig, FramedClient, Server};
use catwalk::SpikeVolley;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

const N: usize = 16;

/// The process-global tracer is shared by every test in this binary.
static TRACER: Mutex<()> = Mutex::new(());

fn tracer_lock() -> MutexGuard<'static, ()> {
    TRACER.lock().unwrap_or_else(|e| e.into_inner())
}

fn native_env() -> bool {
    matches!(BackendKind::from_env(), Ok(BackendKind::Native))
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("catwalk-telemetry-e2e-{tag}-{}", std::process::id()))
}

fn client_cfg() -> ClientConfig {
    ClientConfig {
        read_timeout: Some(Duration::from_secs(5)),
        write_timeout: Some(Duration::from_secs(5)),
        ..ClientConfig::default()
    }
}

fn retry_cfg() -> RetryPolicy {
    RetryPolicy {
        attempts: 3,
        base: Duration::from_millis(5),
        max: Duration::from_millis(20),
        jitter: 0.2,
        seed: 7,
    }
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// One complete serving environment (the `obs.rs` shape): two remote
/// shard hosts plus a standby, behind a registry holding a
/// single-engine model (`default`), an in-process sharded model
/// (`quad`), and a remote-shard model (`dist`).
struct Env {
    server: Arc<Server>,
    registry: Arc<ModelRegistry>,
    addr: String,
    hosts: Vec<ShardHost>,
    srv: std::thread::JoinHandle<()>,
}

fn boot_env(scratch: &PathBuf, tag: &str) -> Env {
    let boot_host = |sub: &str| -> ShardHost {
        boot_shard_host(
            std::path::Path::new("/no-such-dir"),
            &scratch.join(format!("{tag}-{sub}")),
            QosConfig::default(),
        )
        .unwrap()
    };
    let host_a = boot_host("host-a");
    let host_b = boot_host("host-b");
    let standby = boot_host("standby");
    let shard_addrs = vec![host_a.addr.clone(), host_b.addr.clone()];
    let standby_addrs = vec![standby.addr.clone()];

    let spec = ModelSpec {
        n: N,
        theta: 6.0,
        seed: 11,
    };
    let registry = Arc::new(
        ModelRegistry::open(RegistryConfig::default(), "default", spec).unwrap(),
    );
    registry.create_sharded("quad", spec, 2).unwrap();
    registry
        .create_remote("dist", spec, &shard_addrs, standby_addrs, client_cfg(), retry_cfg())
        .unwrap();

    let server = Arc::new(Server::with_registry(registry.clone()));
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    Env {
        server,
        registry,
        addr,
        hosts: vec![host_a, host_b, standby],
        srv,
    }
}

fn shutdown(env: Env) {
    env.server
        .stop_handle()
        .store(true, std::sync::atomic::Ordering::Release);
    env.srv.join().unwrap();
    for h in env.hosts {
        h.shutdown();
    }
    drop(env.registry);
}

fn random_volley(rng: &mut Xoshiro256) -> SpikeVolley {
    SpikeVolley::dense(
        (0..N)
            .map(|_| {
                if rng.gen_bool(0.3) {
                    (rng.gen_f64() * 8.0) as f32
                } else {
                    16.0
                }
            })
            .collect(),
    )
}

/// A text-codec volley with integral spike times, so the line renders
/// identically on every run: `t_max` (16) = silent.
fn text_volley(rng: &mut Xoshiro256) -> String {
    (0..N)
        .map(|_| {
            if rng.gen_bool(0.3) {
                rng.gen_range(8).to_string()
            } else {
                "16".to_string()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn frame_roundtrip(w: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &Request) -> Vec<u8> {
    frame::write_frame(w, FrameType::Request, &frame::encode_request(req).unwrap()).unwrap();
    w.flush().unwrap();
    let (ty, payload) = frame::read_frame(r).unwrap().unwrap();
    assert_eq!(ty, FrameType::Response);
    payload
}

/// Open a raw framed connection negotiated to exactly `version`.
fn raw_framed(addr: &str, version: u16) -> (TcpStream, BufReader<TcpStream>, Vec<u8>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    frame::write_frame(&mut w, FrameType::Hello, &frame::encode_hello(version, version)).unwrap();
    w.flush().unwrap();
    let (ty, ack) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ty, FrameType::Ack);
    assert_eq!(frame::decode_ack(&ack).unwrap().version, version);
    (w, reader, ack)
}

/// The identical deterministic request sequence from `obs.rs`: framed
/// v3 (all three model shapes), text, framed v2, collecting every raw
/// reply byte string. Deliberately avoids `Op::Stats` — stats now
/// carry `uptime_secs`, which two runs can never agree on; the
/// invariant under test is about *serving* replies.
fn run_sequence(addr: &str) -> Vec<Vec<u8>> {
    let mut out: Vec<Vec<u8>> = Vec::new();
    let mut rng = Xoshiro256::new(0x7E1E_E2E);

    let (mut w, mut reader, ack) = raw_framed(addr, frame::VERSION);
    out.push(ack);
    for (i, model) in [None, Some("quad"), Some("dist")].iter().enumerate() {
        let vols: Vec<SpikeVolley> = (0..3).map(|_| random_volley(&mut rng)).collect();
        let mut req = Request::infer(vols).with_id(10 + i as u64);
        if let Some(m) = model {
            req = req.with_model(*m);
        }
        out.push(frame_roundtrip(&mut w, &mut reader, &req));
    }
    for (i, model) in [None, Some("quad")].iter().enumerate() {
        let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
        let mut req = Request::learn(vols).with_id(20 + i as u64);
        if let Some(m) = model {
            req = req.with_model(*m);
        }
        out.push(frame_roundtrip(&mut w, &mut reader, &req));
    }
    out.push(frame_roundtrip(
        &mut w,
        &mut reader,
        &Request::admin(ModelCmd::List).with_id(30),
    ));
    drop((w, reader));

    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let mut lines = vec!["PING".to_string()];
    for model in ["", "@quad ", "@dist "] {
        lines.push(format!("{model}INFER {}", text_volley(&mut rng)));
    }
    lines.push(format!("LEARN {}", text_volley(&mut rng)));
    lines.push(format!("@quad LEARN {}", text_volley(&mut rng)));
    for line in lines {
        w.write_all(format!("{line}\n").as_bytes()).unwrap();
        w.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "text reply for `{line}`");
        out.push(reply.into_bytes());
    }
    drop((w, reader));

    let (mut w, mut reader, ack) = raw_framed(addr, 2);
    out.push(ack);
    let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
    out.push(frame_roundtrip(&mut w, &mut reader, &Request::infer(vols).with_id(40)));
    let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
    out.push(frame_roundtrip(&mut w, &mut reader, &Request::learn(vols).with_id(41)));

    out
}

/// One HTTP/1.0 GET against the exporter: (status line, body).
fn http_get(addr: &std::net::SocketAddr, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(stream, "GET {path} HTTP/1.0\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let (head, body) = text
        .split_once("\r\n\r\n")
        .unwrap_or_else(|| panic!("no header/body split in `{text}`"));
    (
        head.lines().next().unwrap().to_string(),
        body.to_string(),
    )
}

// ----------------------------------------------- bit-identity (tentpole)

/// The tentpole invariant, carried over from PR 9 and widened: the
/// whole telemetry plane — sampler thread, HTTP exporter, *and*
/// rate-1.0 tracing — fully on vs fully off answers the same request
/// sequence with byte-identical replies on framed v3, text, and framed
/// v2, across a single-engine, an in-process sharded, and a
/// remote-shard model.
#[test]
fn telemetry_on_vs_off_replies_bit_identical_on_all_codecs() {
    if !native_env() {
        return;
    }
    let _g = tracer_lock();
    let scratch = temp_dir("bitident");
    let _ = std::fs::remove_dir_all(&scratch);

    // everything on: tracing at rate 1.0 + sampler at a hot 10ms
    // cadence + live HTTP exporter, all while the sequence runs
    obs::reset();
    obs::configure(1.0, 0);
    let env = boot_env(&scratch, "on");
    let tele = telemetry::start(
        env.registry.clone(),
        &TelemetryOptions {
            metrics_addr: Some("127.0.0.1:0".into()),
            interval: Duration::from_millis(10),
            capacity: 128,
        },
    )
    .unwrap();
    let on = run_sequence(&env.addr);
    // prove the plane was really live during the run
    assert!(tele.state().samples_taken() > 0, "sampler never ticked");
    let (status, body) = http_get(&tele.http_addr().unwrap(), "/metrics");
    assert!(status.contains("200"), "{status}");
    telemetry::parse_exposition(&body).unwrap();
    tele.shutdown();
    shutdown(env);

    // everything off: no tracer, no sampler, no listener, no state
    obs::disable();
    obs::reset();
    let env = boot_env(&scratch, "off");
    assert!(env.registry.telemetry().is_none());
    let off = run_sequence(&env.addr);
    shutdown(env);

    assert_eq!(on.len(), off.len(), "sequence shape drifted");
    for (i, (a, b)) in on.iter().zip(&off).enumerate() {
        assert_eq!(
            hex(a),
            hex(b),
            "reply {i} differs between the telemetry-on and telemetry-off runs"
        );
    }
    let _ = std::fs::remove_dir_all(&scratch);
}

// ------------------------------- scrape surfaces + health flip (e2e)

/// The full scrape story against one live environment: `/metrics`
/// parses under the pinned exposition grammar and reports nonzero
/// windowed rates after replayed load; `/healthz` and `/readyz`
/// answer; the admin verbs return the same grammars over the wire;
/// STATS carries the additive identity rows; and killing a remote
/// shard host flips `/readyz` to 503 Degraded with the typed
/// `shard_transport_failed` reason — visible to the sampler within one
/// sampling interval.
#[test]
fn metrics_scrape_rates_and_shard_kill_health_flip() {
    if !native_env() {
        return;
    }
    let _g = tracer_lock();
    obs::disable();
    obs::reset();
    let scratch = temp_dir("scrape");
    let _ = std::fs::remove_dir_all(&scratch);

    let env = boot_env(&scratch, "scrape");
    let interval = Duration::from_millis(50);
    let tele = telemetry::start(
        env.registry.clone(),
        &TelemetryOptions {
            metrics_addr: Some("127.0.0.1:0".into()),
            interval,
            capacity: 256,
        },
    )
    .unwrap();
    let http = tele.http_addr().unwrap();

    // replayed load: bursts over every model shape, spread across
    // several sampling intervals so the series holds real deltas
    let mut client = FramedClient::connect(&env.addr).unwrap();
    let mut rng = Xoshiro256::new(0x70_AD);
    for _burst in 0..3 {
        for model in [None, Some("quad"), Some("dist")] {
            let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
            let mut req = Request::infer(vols);
            if let Some(m) = model {
                req = req.with_model(m);
            }
            let resp = client.call(req).unwrap();
            assert!(matches!(resp.outcome, Outcome::Results(_)), "{:?}", resp.outcome);
        }
        let resp = client
            .call(Request::learn(vec![random_volley(&mut rng)]))
            .unwrap();
        assert!(matches!(resp.outcome, Outcome::Results(_)));
        std::thread::sleep(interval);
    }
    // let the sampler see the post-load counters
    let deadline = Instant::now() + Duration::from_secs(5);
    while tele.state().samples_taken() < 4 {
        assert!(Instant::now() < deadline, "sampler stalled");
        std::thread::sleep(Duration::from_millis(10));
    }

    // --- /metrics: pinned grammar, counters, summaries, nonzero rates
    let (status, body) = http_get(&http, "/metrics");
    assert!(status.contains("200 OK"), "{status}");
    let samples = telemetry::parse_exposition(&body).unwrap();
    let find = |name: &str| {
        samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing {name} in:\n{body}"))
    };
    assert!(find("catwalk_requests_total").value > 0.0);
    assert!(find("catwalk_rate_requests_per_s").value > 0.0, "windowed rate must be nonzero");
    assert!(find("catwalk_rate_volleys_per_s").value > 0.0);
    assert_eq!(find("catwalk_health").value, 0.0, "fresh env must be ready");
    assert!(find("catwalk_samples_total").value >= 4.0);
    assert_eq!(find("catwalk_sample_interval_ms").value, 50.0);
    // per-model and per-shard scopes carry labels
    assert!(samples.iter().any(|s| s.name == "catwalk_model_requests_total"
        && s.labels.contains(&("model".to_string(), "dist".to_string()))));
    assert!(
        samples.iter().any(|s| s.name == "catwalk_shard_rpc_us"
            && s.labels.contains(&("model".to_string(), "dist".to_string()))
            && s.labels.iter().any(|(k, _)| k == "shard")),
        "remote shard rpc summaries must be exported"
    );

    // --- health endpoints
    let (status, body) = http_get(&http, "/healthz");
    assert!(status.contains("200 OK"), "{status}");
    assert_eq!(body, "ok\n");
    let (status, body) = http_get(&http, "/readyz");
    assert!(status.contains("200 OK"), "{status}");
    let report = telemetry::HealthReport::parse(&body).unwrap();
    assert_eq!(report.state, HealthState::Ready);
    assert!(report.reasons.is_empty(), "{report:?}");
    let (status, _) = http_get(&http, "/nope");
    assert!(status.contains("404"), "{status}");

    // --- the same grammars over the admin verbs
    let expo = client.fetch_metrics().unwrap();
    let admin_samples = telemetry::parse_exposition(&expo).unwrap();
    assert!(admin_samples.iter().any(|s| s.name == "catwalk_requests_total"));
    let report = telemetry::HealthReport::parse(&client.fetch_health().unwrap()).unwrap();
    assert_eq!(report.state, HealthState::Ready);

    // --- additive STATS identity rows (satellite): present here, and
    // skipped losslessly by forward-compat parsers (stats.rs property
    // + the python twin splice test)
    let stats = client.stats().unwrap();
    assert!(stats.counters.contains_key("uptime_secs"));
    assert!(stats.counter("start_epoch_secs") > 1_600_000_000, "epoch row");
    assert_eq!(stats.counter("proto_version"), frame::VERSION as u64);

    // --- kill a remote shard host; the transport latch trips on the
    // next traffic, and /readyz flips to Degraded with a typed reason
    env.hosts[0].kill();
    let slot = env.registry.slot(Some("dist")).unwrap();
    let sharded = slot.sharded().unwrap();
    let mut probes = 0;
    while sharded.failed_shards().is_empty() && probes < 200 {
        probes += 1;
        // Ok before the worker notices, Err after — both fine
        for _ in sharded.infer(vec![random_volley(&mut rng)], None) {}
    }
    assert!(!sharded.failed_shards().is_empty(), "latch never tripped");

    let (status, body) = http_get(&http, "/readyz");
    assert!(status.contains("503"), "dead shard must unready: {status}");
    let report = telemetry::HealthReport::parse(&body).unwrap();
    assert_eq!(report.state, HealthState::Degraded);
    assert!(
        report.reasons.iter().any(|r| r.code == "shard_transport_failed"),
        "typed reason missing: {report:?}"
    );
    // the sampler's stored verdict follows within one interval
    std::thread::sleep(interval + Duration::from_millis(50));
    assert_eq!(tele.state().last_health().state, HealthState::Degraded);
    // and the admin verb reports the same degradation
    let report = telemetry::HealthReport::parse(&client.fetch_health().unwrap()).unwrap();
    assert_eq!(report.state, HealthState::Degraded);

    // --- v2 connections are typed-refused both telemetry verbs
    let (mut w, mut reader, _ack) = raw_framed(&env.addr, 2);
    for (id, cmd) in [(300, ModelCmd::FetchMetrics), (301, ModelCmd::FetchHealth)] {
        let payload = frame_roundtrip(
            &mut w,
            &mut reader,
            &Request::admin(cmd).with_id(id),
        );
        let resp = frame::decode_response(&payload).unwrap();
        assert!(
            matches!(resp.outcome, Outcome::Error(ref e) if e.contains("v3")),
            "v2 refusal for id {id}, got {:?}",
            resp.outcome
        );
    }

    let _ = client.quit();
    tele.shutdown();
    shutdown(env);
    let _ = std::fs::remove_dir_all(&scratch);
}
