//! Observability end-to-end gates: the tracing bit-identity invariant
//! (tracing at rate 1.0 vs disabled leaves every reply **byte**
//! identical on all three codecs, for single, sharded, and
//! remote-shard models), cross-host trace stitching over `FLAG_TRACE`,
//! the `CMD_FETCH_TRACE` admin surface plus its v2 typed refusal, the
//! distributed-tier stats rows, and the `CWKT` codec property gates.
//!
//! Every test here touches the process-global tracer, so they all
//! serialize on one mutex — the test harness runs `#[test]` fns in
//! parallel, and two tests flipping [`catwalk::obs::configure`] /
//! [`catwalk::obs::reset`] under each other would race the ring.

use catwalk::dist::RetryPolicy;
use catwalk::obs;
use catwalk::proto::frame::{self, FrameType};
use catwalk::proto::{ModelCmd, Outcome, Request};
use catwalk::qos::replay::{boot_shard_host, ShardHost};
use catwalk::qos::QosConfig;
use catwalk::registry::{ModelRegistry, ModelSpec, RegistryConfig};
use catwalk::rng::Xoshiro256;
use catwalk::runtime::BackendKind;
use catwalk::server::{ClientConfig, FramedClient, Server};
use catwalk::SpikeVolley;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

const N: usize = 16;

/// The process-global tracer is shared by every test in this binary.
static TRACER: Mutex<()> = Mutex::new(());

fn tracer_lock() -> MutexGuard<'static, ()> {
    // a panicked holder already failed its own assertions; the tracer
    // state is re-initialized by each test, so poisoning is harmless
    TRACER.lock().unwrap_or_else(|e| e.into_inner())
}

fn native_env() -> bool {
    matches!(BackendKind::from_env(), Ok(BackendKind::Native))
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("catwalk-obs-e2e-{tag}-{}", std::process::id()))
}

fn client_cfg() -> ClientConfig {
    ClientConfig {
        read_timeout: Some(Duration::from_secs(5)),
        write_timeout: Some(Duration::from_secs(5)),
        ..ClientConfig::default()
    }
}

fn retry_cfg() -> RetryPolicy {
    RetryPolicy {
        attempts: 3,
        base: Duration::from_millis(5),
        max: Duration::from_millis(20),
        jitter: 0.2,
        seed: 7,
    }
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

/// One complete serving environment: two remote shard hosts plus a
/// standby, behind a registry holding a single-engine model
/// (`default`), an in-process sharded model (`quad`), and a
/// remote-shard model (`dist`) — every engine shape a request can
/// route to.
struct Env {
    server: Arc<Server>,
    registry: Arc<ModelRegistry>,
    addr: String,
    hosts: Vec<ShardHost>,
    srv: std::thread::JoinHandle<()>,
}

fn boot_env(scratch: &PathBuf, tag: &str) -> Env {
    let boot_host = |sub: &str| -> ShardHost {
        boot_shard_host(
            std::path::Path::new("/no-such-dir"),
            &scratch.join(format!("{tag}-{sub}")),
            QosConfig::default(),
        )
        .unwrap()
    };
    let host_a = boot_host("host-a");
    let host_b = boot_host("host-b");
    let standby = boot_host("standby");
    let shard_addrs = vec![host_a.addr.clone(), host_b.addr.clone()];
    let standby_addrs = vec![standby.addr.clone()];

    let spec = ModelSpec {
        n: N,
        theta: 6.0,
        seed: 11,
    };
    let registry = Arc::new(
        ModelRegistry::open(RegistryConfig::default(), "default", spec).unwrap(),
    );
    registry.create_sharded("quad", spec, 2).unwrap();
    registry
        .create_remote("dist", spec, &shard_addrs, standby_addrs, client_cfg(), retry_cfg())
        .unwrap();

    let server = Arc::new(Server::with_registry(registry.clone()));
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    Env {
        server,
        registry,
        addr,
        hosts: vec![host_a, host_b, standby],
        srv,
    }
}

fn shutdown(env: Env) {
    env.server
        .stop_handle()
        .store(true, std::sync::atomic::Ordering::Release);
    env.srv.join().unwrap();
    for h in env.hosts {
        h.shutdown();
    }
    drop(env.registry);
}

fn random_volley(rng: &mut Xoshiro256) -> SpikeVolley {
    SpikeVolley::dense(
        (0..N)
            .map(|_| {
                if rng.gen_bool(0.3) {
                    (rng.gen_f64() * 8.0) as f32
                } else {
                    16.0
                }
            })
            .collect(),
    )
}

/// A text-codec volley with integral spike times, so the line renders
/// identically on every run: `t_max` (16) = silent.
fn text_volley(rng: &mut Xoshiro256) -> String {
    (0..N)
        .map(|_| {
            if rng.gen_bool(0.3) {
                rng.gen_range(8).to_string()
            } else {
                "16".to_string()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn frame_roundtrip(w: &mut TcpStream, r: &mut BufReader<TcpStream>, req: &Request) -> Vec<u8> {
    frame::write_frame(w, FrameType::Request, &frame::encode_request(req).unwrap()).unwrap();
    w.flush().unwrap();
    let (ty, payload) = frame::read_frame(r).unwrap().unwrap();
    assert_eq!(ty, FrameType::Response);
    payload
}

/// Open a raw framed connection negotiated to exactly `version`,
/// returning the reader/writer pair and the raw ACK payload.
fn raw_framed(addr: &str, version: u16) -> (TcpStream, BufReader<TcpStream>, Vec<u8>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    frame::write_frame(&mut w, FrameType::Hello, &frame::encode_hello(version, version)).unwrap();
    w.flush().unwrap();
    let (ty, ack) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ty, FrameType::Ack);
    assert_eq!(frame::decode_ack(&ack).unwrap().version, version);
    (w, reader, ack)
}

/// Run the identical deterministic request sequence over all three
/// codecs (framed v3, text, framed v2) against every model shape and
/// return every raw reply byte string, in order. Two environments fed
/// this sequence must answer byte-for-byte identically — the tracing
/// bit-identity gate diffs the collected bytes wholesale.
fn run_sequence(addr: &str) -> Vec<Vec<u8>> {
    let mut out: Vec<Vec<u8>> = Vec::new();
    let mut rng = Xoshiro256::new(0x0B5_E2E);

    // --- framed v3: infer on all three shapes, learn on the local two
    let (mut w, mut reader, ack) = raw_framed(addr, frame::VERSION);
    out.push(ack);
    for (i, model) in [None, Some("quad"), Some("dist")].iter().enumerate() {
        let vols: Vec<SpikeVolley> = (0..3).map(|_| random_volley(&mut rng)).collect();
        let mut req = Request::infer(vols).with_id(10 + i as u64);
        if let Some(m) = model {
            req = req.with_model(*m);
        }
        out.push(frame_roundtrip(&mut w, &mut reader, &req));
    }
    for (i, model) in [None, Some("quad")].iter().enumerate() {
        let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
        let mut req = Request::learn(vols).with_id(20 + i as u64);
        if let Some(m) = model {
            req = req.with_model(*m);
        }
        out.push(frame_roundtrip(&mut w, &mut reader, &req));
    }
    out.push(frame_roundtrip(
        &mut w,
        &mut reader,
        &Request::admin(ModelCmd::List).with_id(30),
    ));
    drop((w, reader));

    // --- text codec: the same shapes over the line protocol
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    let mut lines = vec!["PING".to_string()];
    for model in ["", "@quad ", "@dist "] {
        lines.push(format!("{model}INFER {}", text_volley(&mut rng)));
    }
    lines.push(format!("LEARN {}", text_volley(&mut rng)));
    lines.push(format!("@quad LEARN {}", text_volley(&mut rng)));
    for line in lines {
        w.write_all(format!("{line}\n").as_bytes()).unwrap();
        w.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(!reply.is_empty(), "text reply for `{line}`");
        out.push(reply.into_bytes());
    }
    drop((w, reader));

    // --- framed v2: the back-compat surface (default model only)
    let (mut w, mut reader, ack) = raw_framed(addr, 2);
    out.push(ack);
    let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
    out.push(frame_roundtrip(&mut w, &mut reader, &Request::infer(vols).with_id(40)));
    let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
    out.push(frame_roundtrip(&mut w, &mut reader, &Request::learn(vols).with_id(41)));

    out
}

// ----------------------------------------------- bit-identity (tentpole)

/// The tentpole invariant: tracing is observationally invisible on the
/// wire. Two identically-seeded environments — one sampling every
/// request at `--trace-rate 1.0`, one with tracing disabled — answer
/// the same request sequence with **byte-identical** replies on the
/// framed v3, text, and framed v2 codecs, across a single-engine, an
/// in-process sharded, and a remote-shard model.
#[test]
fn tracing_on_vs_off_replies_bit_identical_on_all_codecs() {
    if !native_env() {
        return;
    }
    let _g = tracer_lock();
    let scratch = temp_dir("bitident");
    let _ = std::fs::remove_dir_all(&scratch);

    obs::reset();
    obs::configure(1.0, 0);
    let env = boot_env(&scratch, "traced");
    let traced = run_sequence(&env.addr);
    assert!(
        !obs::snapshot().is_empty(),
        "a rate-1.0 run must capture spans"
    );
    shutdown(env);

    obs::disable();
    obs::reset();
    let env = boot_env(&scratch, "plain");
    let plain = run_sequence(&env.addr);
    assert!(
        obs::snapshot().is_empty(),
        "a disabled run must capture nothing"
    );
    shutdown(env);

    assert_eq!(traced.len(), plain.len(), "sequence shape drifted");
    for (i, (a, b)) in traced.iter().zip(&plain).enumerate() {
        assert_eq!(
            hex(a),
            hex(b),
            "reply {i} differs between the traced and untraced runs"
        );
    }
    let _ = std::fs::remove_dir_all(&scratch);
}

// ----------------------------------- stitching + CWKT fetch + stats rows

/// A sampled request against the remote-shard model leaves a stitched
/// trace: the coordinator's spans and the shard host's spans (adopted
/// from `FLAG_TRACE` on the forwarded request) share one `TraceId`,
/// and the whole ring exports as a decodable `CWKT` blob over
/// `CMD_FETCH_TRACE`. The distributed tier's stats rows — per-shard
/// `rpc` histograms and per-model replication counters/lag — ride the
/// same run, and a v2 connection is refused both the trace id and the
/// fetch verb with typed errors.
#[test]
fn sampled_trace_stitches_across_hosts_and_exports_cwkt() {
    if !native_env() {
        return;
    }
    let _g = tracer_lock();
    let scratch = temp_dir("stitch");
    let _ = std::fs::remove_dir_all(&scratch);

    obs::reset();
    obs::configure(1.0, 0);
    let env = boot_env(&scratch, "stitch");
    let mut client = FramedClient::connect(&env.addr).unwrap();

    let mut rng = Xoshiro256::new(0x57175);
    for i in 0..4u64 {
        let vols: Vec<SpikeVolley> = (0..2).map(|_| random_volley(&mut rng)).collect();
        let resp = client
            .call(Request::infer(vols).with_model("dist").with_id(100 + i))
            .unwrap();
        assert!(matches!(resp.outcome, Outcome::Results(_)), "{:?}", resp.outcome);
    }

    // a committed save replicates to the standby: replicate/checkpoint
    // spans plus the per-model replication stats rows. The save runs
    // under an installed trace context, as a server-driven save would.
    let coord = scratch.join("coord");
    std::fs::create_dir_all(&coord).unwrap();
    let slot = env.registry.slot(Some("dist")).unwrap();
    {
        let _ckpt_ctx = obs::set_current(obs::begin_request());
        slot.sharded().unwrap().save_checkpoints(&coord.join("dist.ckpt")).unwrap();
    }

    // CMD_FETCH_TRACE returns the ring as a CWKT blob
    let bytes = client.fetch_trace().unwrap();
    assert_eq!(&bytes[..4], obs::TRACE_MAGIC);
    let spans = obs::decode_traces(&bytes).unwrap();
    assert!(!spans.is_empty());

    // every stage of the remote request path shows up
    for stage in [
        obs::Stage::Decode,
        obs::Stage::QueueWait,
        obs::Stage::KernelExec,
        obs::Stage::Scatter,
        obs::Stage::Gather,
        obs::Stage::Rpc,
        obs::Stage::Replicate,
        obs::Stage::Checkpoint,
        obs::Stage::Request,
    ] {
        assert!(
            spans.iter().any(|s| s.stage == stage),
            "no {} span captured",
            stage.name()
        );
    }

    // stitching: some trace id carries an RPC span *and* at least two
    // request spans — the coordinator's own plus the shard host's
    // (adopted over FLAG_TRACE; the hosts share this process's ring)
    let stitched = spans
        .iter()
        .filter(|s| s.stage == obs::Stage::Rpc)
        .any(|rpc| {
            spans
                .iter()
                .filter(|s| s.trace_id == rpc.trace_id && s.stage == obs::Stage::Request)
                .count()
                >= 2
        });
    assert!(
        stitched,
        "no trace id is shared by a coordinator RPC span and a shard-host request span"
    );

    // the CLI's aggregation views work off the same decoded spans
    let agg = obs::aggregate(&spans);
    assert!(agg.iter().any(|s| s.stage == obs::Stage::Rpc && s.count > 0));
    let paths = obs::critical_paths(&spans);
    assert!(!paths.is_empty());
    assert!(
        paths.windows(2).all(|w| w[0].total_us >= w[1].total_us),
        "critical paths must be slowest-first"
    );

    // distributed-tier stats rows: per-shard rpc histograms, per-model
    // replication counters, and the lag gauge (standby fully caught up)
    let stats = client.stats().unwrap();
    for shard in 0..2 {
        let h = stats
            .hist(&format!("model.dist.shard.{shard}.rpc"))
            .unwrap_or_else(|| panic!("missing model.dist.shard.{shard}.rpc row"));
        assert!(h.count > 0);
    }
    assert!(stats.counter("model.dist.replications") >= 1);
    assert_eq!(stats.counter("model.dist.replication_errors"), 0);
    assert_eq!(stats.counter("model.dist.replication_lag_generations"), 0);

    // v2 typed refusals: a trace id on the request, and the fetch verb
    let (mut w, mut reader, _ack) = raw_framed(&env.addr, 2);
    let traced_req = Request::infer(vec![random_volley(&mut rng)])
        .with_trace(9)
        .with_id(200);
    let payload = frame_roundtrip(&mut w, &mut reader, &traced_req);
    let resp = frame::decode_response(&payload).unwrap();
    assert!(
        matches!(resp.outcome, Outcome::Error(ref e) if e.contains("trace ids") && e.contains("v3")),
        "v2 trace id refusal, got {:?}",
        resp.outcome
    );
    let payload = frame_roundtrip(
        &mut w,
        &mut reader,
        &Request::admin(ModelCmd::FetchTrace).with_id(201),
    );
    let resp = frame::decode_response(&payload).unwrap();
    assert!(
        matches!(resp.outcome, Outcome::Error(ref e) if e.contains("v3")),
        "v2 FetchTrace refusal, got {:?}",
        resp.outcome
    );

    let _ = client.quit();
    shutdown(env);
    obs::disable();
    obs::reset();
    let _ = std::fs::remove_dir_all(&scratch);
}

// ------------------------------------------------- CWKT codec properties

/// `CWKT` encode → decode is the identity on random span sets, every
/// strict truncation is rejected, and any single-bit corruption is
/// rejected (CRC32 detects all 1-bit errors; flips in the header hit
/// the magic/schema/length gates first).
#[test]
fn prop_cwkt_roundtrip_rejects_truncation_and_bitflips() {
    let mut rng = Xoshiro256::new(0xCC_4B17);
    for case in 0..40 {
        let count = rng.gen_range(64);
        let recs: Vec<obs::SpanRecord> = (0..count)
            .map(|_| obs::SpanRecord {
                trace_id: rng.next_u64(),
                stage: obs::Stage::from_u8(rng.gen_range(10) as u8).unwrap(),
                flags: (rng.next_u64() & 0x0F) as u8,
                tag: rng.next_u64() as u32,
                start_us: rng.next_u64() >> 20,
                dur_us: rng.next_u64() >> 40,
            })
            .collect();
        let bytes = obs::encode_traces(&recs);
        assert_eq!(obs::decode_traces(&bytes).unwrap(), recs, "case {case}");

        let cut = rng.gen_range(bytes.len());
        assert!(
            obs::decode_traces(&bytes[..cut]).is_err(),
            "case {case}: truncation to {cut} bytes accepted"
        );

        let mut flipped = bytes.clone();
        let at = rng.gen_range(flipped.len());
        flipped[at] ^= 1 << rng.gen_range(8);
        assert!(
            obs::decode_traces(&flipped).is_err(),
            "case {case}: bit flip at byte {at} accepted"
        );
    }
}

// --------------------------------------- ring wrap-around (PR 10 gate)

/// The span ring under wrap-around: concurrent writers push enough
/// records that the ticket counter laps the 65 536-slot ring twice,
/// while snapshot readers run the whole time. Every record a snapshot
/// returns must be internally consistent — each field is a pure
/// function of its `trace_id`, so a torn slot (fields mixed from two
/// different writes surviving the seqlock check) trips an assertion —
/// and no `trace_id` may appear twice in one snapshot (each id is
/// pushed exactly once; a duplicate would mean one write landed in two
/// slots). After the writers drain, the ring must be exactly full.
#[test]
fn span_ring_wraparound_yields_no_torn_or_duplicate_records() {
    let _guard = tracer_lock();
    obs::configure(1.0, 0);
    obs::reset();

    const WRITERS: u64 = 4;
    // two full laps of the ring across all writers
    const PER_WRITER: u64 = (obs::DEFAULT_TRACE_CAPACITY as u64 / WRITERS) * 2;
    let expected_tag = |w: u64, i: u64| -> u32 { ((i as u32) ^ ((w as u32) << 24)) | 1 };
    let expected_dur = |w: u64, i: u64| -> u64 { (w << 40) | i };

    let check = |records: &[obs::SpanRecord]| {
        let mut seen = std::collections::HashSet::with_capacity(records.len());
        for r in records {
            let w = (r.trace_id >> 48) - 1;
            let i = r.trace_id & 0xffff_ffff_ffff;
            assert!(w < WRITERS, "impossible writer id in {r:?}");
            assert!(i < PER_WRITER, "impossible sequence number in {r:?}");
            assert_eq!(r.tag, expected_tag(w, i), "torn record {r:?}");
            assert_eq!(r.dur_us, expected_dur(w, i), "torn record {r:?}");
            assert_eq!(r.stage, obs::Stage::Rpc, "torn record {r:?}");
            assert!(seen.insert(r.trace_id), "duplicated record {r:?}");
        }
    };

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let epoch = std::time::Instant::now();
    let writers: Vec<_> = (0..WRITERS)
        .map(|w| {
            std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    let ctx = obs::TraceCtx {
                        id: ((w + 1) << 48) | i,
                        sampled: true,
                    };
                    obs::record(
                        ctx,
                        obs::Stage::Rpc,
                        expected_tag(w, i),
                        epoch,
                        Duration::from_micros(expected_dur(w, i)),
                    );
                }
            })
        })
        .collect();
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let stop = stop.clone();
            std::thread::spawn(move || loop {
                // at least one mid-flight check even if the writers
                // finish before this thread gets scheduled
                check(&obs::snapshot());
                if stop.load(std::sync::atomic::Ordering::Acquire) {
                    break;
                }
            })
        })
        .collect();
    for h in writers {
        h.join().expect("writer panicked");
    }
    stop.store(true, std::sync::atomic::Ordering::Release);
    for h in readers {
        h.join().expect("reader panicked");
    }

    // quiescent: every slot published, nothing torn, nothing doubled
    let last = obs::snapshot();
    assert_eq!(
        last.len(),
        obs::DEFAULT_TRACE_CAPACITY,
        "ring must be exactly full after lapping it twice"
    );
    check(&last);

    obs::disable();
    obs::reset();
}
