//! Runtime round-trip and cross-layer conformance tests.
//!
//! These exercise the full L3 serving stack (runtime backend →
//! coordinator → batcher → TCP server) on the default **native** backend,
//! so they run in a fresh checkout with no artifacts. When `artifacts/`
//! exists (after `make artifacts`) the same tests validate the real
//! manifest; with `CATWALK_BACKEND=xla` and `--features xla` they become
//! the PJRT conformance suite.

use catwalk::coordinator::{BatcherConfig, DynamicBatcher, TnnHandle};
use catwalk::neuron::behavior::rnl_first_crossing;
use catwalk::rng::Xoshiro256;
use catwalk::runtime::plan::{detect_simd, ForwardArgs, KernelPath, KernelPlan, SimdLevel};
use catwalk::runtime::{Runtime, Tensor};
use catwalk::server::{Client, Server};
use catwalk::sim::Simulator;
use catwalk::tnn::{wta, Column};
use catwalk::topk::TopkSelector;
use catwalk::volley::SpikeVolley;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The top-k kernel and the gate-level netlist of the same selector agree
/// bit-for-bit — the strongest L1-vs-hardware conformance signal in the
/// repo.
#[test]
fn topk_kernel_matches_gate_level_netlist() {
    let rt = Runtime::open("artifacts").unwrap();
    let t_max = rt.manifest().t_max;
    for n in [16usize, 32, 64] {
        let exe = rt.load(&format!("topk_eval_n{n}_k2_b64")).unwrap();
        let sel = TopkSelector::catwalk(n, 2).unwrap();
        let nl = sel.to_netlist("sel").unwrap();
        let mut rng = Xoshiro256::new(n as u64);

        // 64 random waveforms [b, n, t]
        let mut data = vec![0f32; 64 * n * t_max];
        let mut waves = vec![vec![vec![false; t_max]; n]; 64];
        for (b, wave) in waves.iter_mut().enumerate() {
            for (i, lane) in wave.iter_mut().enumerate() {
                // temporal pulses (realistic) + pure noise (adversarial)
                if rng.gen_bool(0.3) {
                    let s = rng.gen_range(8);
                    let w = 1 + rng.gen_range(7);
                    for (t, v) in lane.iter_mut().enumerate() {
                        *v = t >= s && t < s + w;
                    }
                }
                if rng.gen_bool(0.2) {
                    for v in lane.iter_mut() {
                        *v ^= rng.gen_bool(0.3);
                    }
                }
                for (t, &v) in lane.iter().enumerate() {
                    data[(b * n + i) * t_max + t] = v as u32 as f32;
                }
            }
        }
        let out = exe
            .run(&[Tensor::new(vec![64, n, t_max], data).unwrap()])
            .unwrap();
        let taps = &out[0]; // [64, 2, t_max]

        for (b, wave) in waves.iter().enumerate() {
            let mut sim = Simulator::new(&nl);
            for t in 0..t_max {
                let bits: Vec<bool> = (0..n).map(|i| wave[i][t]).collect();
                let hw = sim.step(&bits);
                for j in 0..2 {
                    let kernel = taps.data[(b * 2 + j) * t_max + t] > 0.5;
                    assert_eq!(hw[j], kernel, "n={n} b={b} tap={j} t={t}");
                }
            }
        }
    }
}

/// Satellite conformance gate: the native backend and the behavioral
/// golden model (`neuron::behavior::rnl_first_crossing`) produce
/// identical first-crossing times and WTA winners on seeded random
/// volleys. Volleys carry at most k = 2 active lanes so the Catwalk clip
/// baked into the forward kernel never engages and the un-clipped golden
/// model applies exactly.
#[test]
fn native_backend_matches_behavior_golden_model() {
    let n = 16;
    let theta = 5u32;
    let handle = TnnHandle::open("artifacts", n, theta as f32, 3).unwrap();
    let c = handle.c;

    // integer weights so the golden model (u32 arithmetic) is exact
    let mut rng = Xoshiro256::new(99);
    let weights: Vec<f32> = (0..c * n).map(|_| rng.gen_range(8) as f32).collect();
    handle
        .set_weights(Tensor::new(vec![c, n], weights.clone()).unwrap())
        .unwrap();

    let volleys: Vec<Vec<f32>> = (0..48)
        .map(|_| {
            let mut v = vec![handle.t_max as f32; n];
            for lane in rng.sample_indices(n, 2) {
                v[lane] = rng.gen_range(8) as f32;
            }
            v
        })
        .collect();
    let results = handle.infer(volleys.clone()).unwrap();

    for (volley, res) in volleys.iter().zip(&results) {
        let st: Vec<Option<u32>> = volley
            .iter()
            .map(|&s| {
                if s < handle.t_max as f32 {
                    Some(s as u32)
                } else {
                    None
                }
            })
            .collect();
        let mut expect_times = Vec::with_capacity(c);
        for ci in 0..c {
            let wt: Vec<u32> = weights[ci * n..(ci + 1) * n]
                .iter()
                .map(|&w| w as u32)
                .collect();
            let t = rnl_first_crossing(&st, &wt, theta, handle.t_max as u32)
                .map(|t| t as f32)
                .unwrap_or(handle.t_max as f32);
            expect_times.push(t);
        }
        assert_eq!(res.times, expect_times, "volley {volley:?}");
        assert_eq!(res.winner, wta(&expect_times), "volley {volley:?}");
    }
}

/// Backend column forward equals the native Rust behavioral column when
/// both use identical weights — L2/L3 conformance.
#[test]
fn backend_forward_matches_native_column() {
    let n = 16;
    let handle = TnnHandle::open("artifacts", n, 6.0, 9).unwrap();
    // mirror the weights into a native column
    let w = handle.weights().unwrap();
    let mut native = Column::new(n, handle.c, 6.0, Some(2), 0);
    for c in 0..handle.c {
        for i in 0..n {
            native.weights[c][i] = w.at2(c, i);
        }
    }
    let mut rng = Xoshiro256::new(5);
    let volleys: Vec<Vec<f32>> = (0..32)
        .map(|_| {
            (0..n)
                .map(|_| {
                    if rng.gen_bool(0.35) {
                        rng.gen_range(8) as f32
                    } else {
                        16.0
                    }
                })
                .collect()
        })
        .collect();
    let results = handle.infer(volleys.clone()).unwrap();
    for (v, r) in volleys.iter().zip(&results) {
        let nat = native.forward(v);
        assert_eq!(r.times, nat.times, "volley {v:?}");
        assert_eq!(r.winner, nat.winner);
    }
}

/// STDP learning through the backend moves weights and stays bounded.
#[test]
fn learn_updates_weights_within_bounds() {
    let handle = TnnHandle::open("artifacts", 16, 4.0, 3).unwrap();
    let w0 = handle.weights().unwrap();
    let mut rng = Xoshiro256::new(8);
    for _ in 0..5 {
        let volleys: Vec<Vec<f32>> = (0..handle.b)
            .map(|_| {
                (0..16)
                    .map(|_| {
                        if rng.gen_bool(0.4) {
                            rng.gen_range(6) as f32
                        } else {
                            16.0
                        }
                    })
                    .collect()
            })
            .collect();
        handle.learn(volleys).unwrap();
    }
    let w1 = handle.weights().unwrap();
    assert_ne!(w0.data, w1.data, "weights must move");
    for &w in &w1.data {
        assert!((0.0..=7.0).contains(&w), "weight {w} out of bounds");
    }
}

/// Conformance gate for the kernel dispatch paths: across sparsity
/// levels (all-silent through fully dense, fractional spike times and
/// weights, clipped and unclipped) the SIMD dense sweep, the
/// software-Catwalk compacted path and the auto cutover are
/// **bit-identical** — spike times and WTA winners — to the scalar dense
/// golden model (`KernelPath::Scalar`, the loop `ref.py::rnl_column_ref`
/// mirrors).
#[test]
fn kernel_path_conformance_gate() {
    let t_max = 16usize;
    let scalar_plan = KernelPlan::with_path(KernelPath::Scalar);
    let mut rng = Xoshiro256::new(2024);
    for &density in &[0.0, 0.05, 0.1, 0.25, 0.5, 1.0] {
        for _ in 0..10 {
            let (b, c, n) = (8, 6, 48);
            let spikes: Vec<f32> = (0..b * n)
                .map(|_| {
                    if rng.gen_bool(density) {
                        (rng.gen_f64() * 10.0) as f32
                    } else {
                        t_max as f32
                    }
                })
                .collect();
            let weights: Vec<f32> = (0..c * n).map(|_| (rng.gen_f64() * 7.0) as f32).collect();
            let theta = 1.0 + (rng.gen_f64() * 10.0) as f32;
            let st = Tensor::new(vec![b, n], spikes).unwrap();
            let wt = Tensor::new(vec![c, n], weights).unwrap();
            for k_clip in [None, Some(2.0)] {
                let args = ForwardArgs::new(&st, &wt, theta, t_max).k_clip(k_clip);
                let scalar = scalar_plan.forward(&args);
                for path in [KernelPath::Simd, KernelPath::Compacted, KernelPath::Auto] {
                    let plan = KernelPlan::with_path(path);
                    let got = plan.forward(&args);
                    let sb: Vec<u32> = scalar.data.iter().map(|x| x.to_bits()).collect();
                    let gb: Vec<u32> = got.data.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(
                        sb, gb,
                        "{path:?} times diverge at density {density} clip {k_clip:?}"
                    );
                    let (ms, mg) = (
                        scalar_plan.wta(&scalar, t_max),
                        plan.wta(&got, t_max),
                    );
                    assert_eq!(
                        ms.data, mg.data,
                        "{path:?} winners diverge at density {density} clip {k_clip:?}"
                    );
                }
            }
        }
    }
}

/// Every explicit kernel path — not just the serving default — matches
/// the behavioral golden model `rnl_first_crossing` on integer problems,
/// at every SIMD level the host can run. This pins all three rebuilt
/// paths directly to the model the python oracle (`ref.py`) is itself
/// verified against, rather than only to each other.
#[test]
fn all_kernel_paths_match_behavior_golden_model() {
    let t_max = 16usize;
    let theta = 6u32;
    let mut rng = Xoshiro256::new(777);
    let (b, c, n) = (12, 5, 24);
    for _ in 0..20 {
        let spikes: Vec<f32> = (0..b * n)
            .map(|_| {
                if rng.gen_bool(0.3) {
                    rng.gen_range(8) as f32
                } else {
                    t_max as f32
                }
            })
            .collect();
        let weights: Vec<f32> = (0..c * n).map(|_| rng.gen_range(8) as f32).collect();
        let st = Tensor::new(vec![b, n], spikes.clone()).unwrap();
        let wt = Tensor::new(vec![c, n], weights.clone()).unwrap();
        let args = ForwardArgs::new(&st, &wt, theta as f32, t_max);
        for path in [
            KernelPath::Scalar,
            KernelPath::Simd,
            KernelPath::Compacted,
            KernelPath::Auto,
        ] {
            let mut levels = vec![SimdLevel::None, SimdLevel::Sse2];
            if detect_simd() == SimdLevel::Avx2 {
                levels.push(SimdLevel::Avx2);
            }
            for level in levels {
                let times = KernelPlan::with_path(path).with_simd(level).forward(&args);
                for bi in 0..b {
                    let stv: Vec<Option<u32>> = spikes[bi * n..(bi + 1) * n]
                        .iter()
                        .map(|&s| if s < t_max as f32 { Some(s as u32) } else { None })
                        .collect();
                    for ci in 0..c {
                        let wv: Vec<u32> = weights[ci * n..(ci + 1) * n]
                            .iter()
                            .map(|&w| w as u32)
                            .collect();
                        let expect = rnl_first_crossing(&stv, &wv, theta, t_max as u32)
                            .map(|t| t as f32)
                            .unwrap_or(t_max as f32);
                        assert_eq!(
                            times.at2(bi, ci),
                            expect,
                            "{path:?}/{level:?} row {bi} col {ci}"
                        );
                    }
                }
            }
        }
    }
}

/// Sparse-encoded volleys through the full engine path (pack → backend →
/// unpack) match the behavioral golden model exactly, across sparsity
/// levels. Volleys carry at most 2 active lanes so the k = 2 clip baked
/// into the kernel never engages and the un-clipped golden model applies
/// exactly — denser inputs are covered by the kernel gate above.
#[test]
fn sparse_volleys_match_golden_model_end_to_end() {
    let n = 16;
    let theta = 5u32;
    let handle = TnnHandle::open("artifacts", n, theta as f32, 17).unwrap();
    let c = handle.c;
    let t_max = handle.t_max;

    let mut rng = Xoshiro256::new(404);
    let weights: Vec<f32> = (0..c * n).map(|_| rng.gen_range(8) as f32).collect();
    handle
        .set_weights(Tensor::new(vec![c, n], weights.clone()).unwrap())
        .unwrap();

    for active_lanes in [0usize, 1, 2] {
        let volleys: Vec<SpikeVolley> = (0..24)
            .map(|_| {
                let pairs: Vec<(usize, f32)> = rng
                    .sample_indices(n, active_lanes)
                    .into_iter()
                    .map(|lane| (lane, rng.gen_range(8) as f32))
                    .collect();
                SpikeVolley::sparse(n, pairs, t_max).unwrap()
            })
            .collect();
        let results = handle.infer(volleys.clone()).unwrap();
        for (v, res) in volleys.iter().zip(&results) {
            let dense = v.dense_times(t_max);
            let st: Vec<Option<u32>> = dense
                .iter()
                .map(|&s| if s < t_max as f32 { Some(s as u32) } else { None })
                .collect();
            let mut expect_times = Vec::with_capacity(c);
            for ci in 0..c {
                let wt: Vec<u32> = weights[ci * n..(ci + 1) * n]
                    .iter()
                    .map(|&w| w as u32)
                    .collect();
                let t = rnl_first_crossing(&st, &wt, theta, t_max as u32)
                    .map(|t| t as f32)
                    .unwrap_or(t_max as f32);
                expect_times.push(t);
            }
            assert_eq!(res.times, expect_times, "volley {v:?}");
            assert_eq!(res.winner, wta(&expect_times), "volley {v:?}");
        }
    }
}

/// Dynamic batcher under concurrency: every request gets exactly one
/// result, batches actually form, latency is recorded.
#[test]
fn batcher_under_concurrent_load() {
    let handle = TnnHandle::open("artifacts", 16, 6.0, 1).unwrap();
    let metrics = handle.metrics.clone();
    let batcher = Arc::new(DynamicBatcher::start(
        handle,
        BatcherConfig {
            max_batch: 32,
            flush_after: std::time::Duration::from_millis(3),
            learn: false,
        },
    ));
    let n_threads = 8;
    let per_thread = 40;
    let results = catwalk::coordinator::pool::par_map(
        n_threads,
        (0..n_threads).collect::<Vec<_>>(),
        |tid| {
            let mut rng = Xoshiro256::new(tid as u64);
            let mut ok = 0;
            for _ in 0..per_thread {
                let volley: Vec<f32> = (0..16)
                    .map(|_| {
                        if rng.gen_bool(0.3) {
                            rng.gen_range(8) as f32
                        } else {
                            16.0
                        }
                    })
                    .collect();
                let r = batcher.submit(volley).unwrap();
                assert_eq!(r.times.len(), 8);
                ok += 1;
            }
            ok
        },
    );
    let total: usize = results.iter().sum();
    assert_eq!(total, n_threads * per_thread);
    assert_eq!(metrics.counter("requests"), total as u64);
    assert_eq!(metrics.counter("batched_requests"), total as u64);
    let batches = metrics.counter("batches");
    assert!(batches > 0 && batches < total as u64, "batches={batches}");
    assert!(metrics.summary("request_latency").unwrap().count == total as u64);
}

/// Timing: a partial batch (far fewer requests than `max_batch`) is
/// flushed by the `flush_after` timer, not held hostage waiting for a
/// full batch.
#[test]
fn batcher_flushes_partial_batch_on_timeout() {
    let handle = TnnHandle::open("artifacts", 16, 6.0, 21).unwrap();
    let metrics = handle.metrics.clone();
    let batcher = DynamicBatcher::start(
        handle,
        BatcherConfig {
            max_batch: 32,
            flush_after: Duration::from_millis(5),
            learn: false,
        },
    );
    let t0 = Instant::now();
    let oks = catwalk::coordinator::pool::par_map(3, (0..3).collect::<Vec<_>>(), |_| {
        batcher.submit(vec![16.0f32; 16]).unwrap().times.len()
    });
    assert_eq!(oks, vec![8, 8, 8]);
    // generous bound: the 5 ms flush timer fired, we never waited for 32
    // requests that will not come
    assert!(t0.elapsed() < Duration::from_secs(5), "{:?}", t0.elapsed());
    assert_eq!(metrics.counter("requests"), 3);
    assert_eq!(metrics.counter("batched_requests"), 3);
    let batches = metrics.counter("batches");
    assert!((1..=3).contains(&batches), "batches={batches}");
}

/// Shutdown with requests still queued: the batcher flushes them (every
/// submitter gets a real result, not an error), then rejects new work.
#[test]
fn batcher_shutdown_flushes_pending_requests() {
    let handle = TnnHandle::open("artifacts", 16, 6.0, 22).unwrap();
    let metrics = handle.metrics.clone();
    // flush timer effectively never fires: only shutdown can flush
    let batcher = Arc::new(DynamicBatcher::start(
        handle,
        BatcherConfig {
            max_batch: 64,
            flush_after: Duration::from_secs(30),
            learn: false,
        },
    ));
    let submitters: Vec<_> = (0..6)
        .map(|_| {
            let b = batcher.clone();
            std::thread::spawn(move || b.submit(vec![16.0f32; 16]))
        })
        .collect();
    // wait until all six requests are enqueued (bounded spin)
    let deadline = Instant::now() + Duration::from_secs(10);
    while metrics.counter("requests") < 6 {
        assert!(Instant::now() < deadline, "submitters never enqueued");
        std::thread::sleep(Duration::from_millis(1));
    }
    batcher.shutdown();
    for s in submitters {
        let res = s.join().unwrap().expect("pending request must be served");
        assert_eq!(res.times.len(), 8);
    }
    assert_eq!(metrics.counter("batched_requests"), 6);
    // post-shutdown submissions are rejected cleanly
    let err = batcher.submit(vec![16.0f32; 16]).unwrap_err();
    assert!(err.to_string().contains("shut down"), "{err}");
}

/// Rejects malformed volleys without poisoning the batcher.
#[test]
fn batcher_rejects_bad_width_then_recovers() {
    let handle = TnnHandle::open("artifacts", 16, 6.0, 2).unwrap();
    let batcher = DynamicBatcher::start(handle, BatcherConfig::default());
    let err = batcher.submit(vec![1.0; 3]).unwrap_err();
    assert!(err.to_string().contains("width"), "{err}");
    // still serves good requests afterwards
    let ok = batcher.submit(vec![16.0; 16]).unwrap();
    assert_eq!(ok.times.len(), 8);
}

/// Full TCP serving loop: server + concurrent clients + stats + shutdown.
#[test]
fn tcp_server_end_to_end() {
    let handle = TnnHandle::open("artifacts", 16, 6.0, 4).unwrap();
    let server = Arc::new(Server::new(handle, BatcherConfig::default()));
    let stop = server.stop_handle();
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let port = port_rx.recv().unwrap();
    let addr = format!("127.0.0.1:{port}");

    let oks = catwalk::coordinator::pool::par_map(4, (0..4).collect::<Vec<_>>(), |tid| {
        let mut client = Client::connect(&addr).unwrap();
        let mut rng = Xoshiro256::new(tid as u64 + 100);
        let mut ok = 0;
        for _ in 0..20 {
            let volley: Vec<f32> = (0..16)
                .map(|_| {
                    if rng.gen_bool(0.4) {
                        rng.gen_range(8) as f32
                    } else {
                        16.0
                    }
                })
                .collect();
            let (winner, times) = client.infer(&volley).unwrap();
            assert_eq!(times.len(), 8);
            assert!(winner >= -1 && winner < 8);
            ok += 1;
        }
        // learning path through TCP too
        let (_, times) = client.learn(&[0.0; 16]).unwrap();
        assert_eq!(times.len(), 8);
        client.quit().unwrap();
        ok
    });
    assert_eq!(oks.iter().sum::<usize>(), 80);

    stop.store(true, std::sync::atomic::Ordering::Release);
    srv.join().unwrap();
}

/// `SPARSE`/`SLEARN` over TCP: sparse requests produce exactly the dense
/// path's results (the reply lists precisely the columns the dense reply
/// shows firing), grammar violations get `ERR` without poisoning the
/// connection, and both encodings mix freely on one connection.
#[test]
fn tcp_sparse_protocol_end_to_end() {
    let n = 16;
    let handle = TnnHandle::open("artifacts", n, 6.0, 23).unwrap();
    let t_max = handle.t_max;
    let server = Arc::new(Server::new(handle, BatcherConfig::default()));
    let stop = server.stop_handle();
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    let mut client = Client::connect(&addr).unwrap();

    let mut rng = Xoshiro256::new(314);
    for _ in 0..20 {
        let active = rng.gen_range(3);
        let pairs: Vec<(usize, f32)> = rng
            .sample_indices(n, active)
            .into_iter()
            .map(|lane| (lane, rng.gen_range(8) as f32))
            .collect();
        let dense = SpikeVolley::sparse(n, pairs.clone(), t_max)
            .unwrap()
            .dense_times(t_max);

        let (dw, dtimes) = client.infer(&dense).unwrap();
        let (sw, spikes) = client.infer_sparse(&pairs).unwrap();
        assert_eq!(dw, sw, "volley {pairs:?}");
        let fired: Vec<(usize, f32)> = dtimes
            .iter()
            .enumerate()
            .filter(|&(_, &t)| t < t_max as f32)
            .map(|(c, &t)| (c, t))
            .collect();
        assert_eq!(spikes, fired, "volley {pairs:?}");
    }

    // sparse learning path
    let (_, _) = client.learn_sparse(&[(0, 0.0), (3, 1.0)]).unwrap();
    // grammar/range violations answer ERR but the connection survives
    assert!(client.infer_sparse(&[(99, 1.0)]).is_err());
    let (w, _) = client.infer_sparse(&[]).unwrap();
    assert_eq!(w, -1, "all-silent volley cannot have a winner");
    client.quit().unwrap();

    stop.store(true, std::sync::atomic::Ordering::Release);
    srv.join().unwrap();
}
