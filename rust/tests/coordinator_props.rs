//! Property tests on coordinator/CLI/report invariants (no PJRT needed).

use catwalk::cli::Args;
use catwalk::coordinator::pool::{par_map, ThreadPool};
use catwalk::coordinator::Metrics;
use catwalk::quickprop::{forall, FnGen, UsizeRange};
use catwalk::report::{Json, Table};
use catwalk::rng::Xoshiro256;
use catwalk::runtime::plan::{ForwardArgs, KernelPath, KernelPlan};
use catwalk::runtime::Tensor;
use catwalk::volley::SpikeVolley;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

const T_MAX: usize = 16;

/// par_map(f) == map(f) for arbitrary input sizes and thread counts.
#[test]
fn prop_par_map_equals_sequential_map() {
    forall(
        1,
        64,
        &FnGen(|rng: &mut Xoshiro256| {
            let len = rng.gen_range(200);
            let threads = 1 + rng.gen_range(12);
            let xs: Vec<u64> = (0..len).map(|_| rng.next_u64() % 1000).collect();
            (threads, xs)
        }),
        |(threads, xs)| {
            let expect: Vec<u64> = xs.iter().map(|x| x * 3 + 1).collect();
            let got = par_map(*threads, xs.clone(), |x| x * 3 + 1);
            got == expect
        },
    );
}

/// Every submitted pool job runs exactly once regardless of job count /
/// thread count / interleaved panics.
#[test]
fn prop_pool_runs_each_job_once() {
    forall(
        2,
        24,
        &FnGen(|rng: &mut Xoshiro256| {
            (1 + rng.gen_range(8), rng.gen_range(150))
        }),
        |&(threads, jobs)| {
            let pool = ThreadPool::new(threads);
            let counter = Arc::new(AtomicU64::new(0));
            for i in 0..jobs {
                let c = counter.clone();
                pool.submit(move || {
                    if i % 17 == 3 {
                        panic!("injected");
                    }
                    c.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            }
            pool.wait_idle();
            let expected = (0..jobs).filter(|i| i % 17 != 3).count() as u64;
            counter.load(Ordering::Relaxed) == expected
        },
    );
}

/// Histogram quantiles are monotone in q for arbitrary samples.
#[test]
fn prop_metrics_quantiles_monotone() {
    forall(
        3,
        128,
        &FnGen(|rng: &mut Xoshiro256| {
            let n = 1 + rng.gen_range(200);
            (0..n)
                .map(|_| rng.gen_range(1_000_000) as u64)
                .collect::<Vec<u64>>()
        }),
        |samples| {
            let m = Metrics::new();
            for &us in samples {
                m.record("x", Duration::from_micros(us));
            }
            let s = m.summary("x").unwrap();
            s.p50_us <= s.p95_us && s.p95_us <= s.p99_us && s.count == samples.len() as u64
        },
    );
}

/// CLI round-trip: any (name, value) pair survives parsing in both
/// `--k v` and `--k=v` forms.
#[test]
fn prop_cli_roundtrip() {
    forall(
        4,
        128,
        &UsizeRange { lo: 0, hi: 1_000_000 },
        |&v| {
            let a = Args::parse(vec![
                "repro".into(),
                "x".into(),
                "--val".into(),
                v.to_string(),
            ])
            .unwrap();
            let b = Args::parse(vec!["repro".into(), "x".into(), format!("--val={v}")]).unwrap();
            a.get_usize("val", 0).unwrap() == v && b.get_usize("val", 0).unwrap() == v
        },
    );
}

/// JSON writer always emits parseable JSON (checked against the runtime's
/// own manifest parser).
#[test]
fn prop_json_writer_parses_back() {
    use catwalk::runtime::manifest::JsonValue;
    forall(
        5,
        256,
        &FnGen(|rng: &mut Xoshiro256| {
            let n = rng.gen_range(8);
            let mut kvs = Vec::new();
            for i in 0..n {
                let v = match rng.gen_range(4) {
                    0 => Json::Num(rng.gen_range(1000) as f64),
                    1 => Json::Str(format!("s{}\"quote\\slash\n", rng.next_u32())),
                    2 => Json::Bool(rng.gen_bool(0.5)),
                    _ => Json::Arr(vec![Json::Num(1.5), Json::Null]),
                };
                kvs.push((format!("k{i}"), v));
            }
            Json::Obj(kvs).render()
        }),
        |text| JsonValue::parse(text).is_ok(),
    );
}

/// Sparse ↔ dense `SpikeVolley` round-trips are lossless for arbitrary
/// canonical volleys, including the all-silent and all-spiking corners
/// (drawn with positive probability every run).
#[test]
fn prop_volley_roundtrip_lossless() {
    forall(
        7,
        256,
        &FnGen(|rng: &mut Xoshiro256| {
            let n = 1 + rng.gen_range(64);
            // density corners drawn explicitly: 0 = all-silent, 1 = all-spiking
            let density = match rng.gen_range(5) {
                0 => 0.0,
                1 => 1.0,
                _ => rng.gen_f64(),
            };
            (0..n)
                .map(|_| {
                    if rng.gen_bool(density) {
                        (rng.gen_f64() * T_MAX as f64) as f32
                    } else {
                        T_MAX as f32
                    }
                })
                .collect::<Vec<f32>>()
        }),
        |times| {
            let v = SpikeVolley::dense(times.clone());
            let sparse = v.to_sparse(T_MAX);
            // canonical input -> round-trip is the exact identity
            sparse.to_dense(T_MAX) == v
                && sparse.to_dense(T_MAX).to_sparse(T_MAX) == sparse
                && sparse.stats(T_MAX) == v.stats(T_MAX)
                && SpikeVolley::parse_sparse(&v.encode_sparse(T_MAX), times.len(), T_MAX)
                    .unwrap()
                    .dense_times(T_MAX)
                    == *times
        },
    );
}

/// Scalar == SIMD == catwalk-compacted == auto forward, bit for bit,
/// across random (n, c, t_max, sparsity) — the all-silent and
/// fully-dense corners drawn with positive probability every run — at
/// random cutovers, thresholds and clips.
#[test]
fn prop_kernel_paths_bit_identical() {
    forall(
        8,
        64,
        &FnGen(|rng: &mut Xoshiro256| {
            let b = 1 + rng.gen_range(6);
            let c = 1 + rng.gen_range(8);
            let n = 1 + rng.gen_range(48);
            let t_max = 4 + rng.gen_range(28);
            // density corners drawn explicitly: 0 = all-silent, 1 = fully dense
            let density = match rng.gen_range(5) {
                0 => 0.0,
                1 => 1.0,
                _ => rng.gen_f64(),
            };
            let spikes: Vec<f32> = (0..b * n)
                .map(|_| {
                    if rng.gen_bool(density) {
                        (rng.gen_f64() * t_max as f64) as f32
                    } else {
                        t_max as f32
                    }
                })
                .collect();
            let weights: Vec<f32> = (0..c * n).map(|_| (rng.gen_f64() * 7.0) as f32).collect();
            let theta = (rng.gen_f64() * 12.0) as f32; // includes the theta = 0 edge
            let cutover = rng.gen_f64() as f32; // auto decisions at arbitrary cutovers
            (b, c, n, t_max, spikes, weights, theta, cutover)
        }),
        |(b, c, n, t_max, spikes, weights, theta, cutover)| {
            let st = Tensor::new(vec![*b, *n], spikes.clone()).unwrap();
            let wt = Tensor::new(vec![*c, *n], weights.clone()).unwrap();
            [None, Some(2.0)].into_iter().all(|k_clip| {
                let args = ForwardArgs::new(&st, &wt, *theta, *t_max).k_clip(k_clip);
                let bits = |t: Tensor| -> Vec<u32> {
                    t.data.iter().map(|x| x.to_bits()).collect()
                };
                let scalar = bits(KernelPlan::with_path(KernelPath::Scalar).forward(&args));
                [KernelPath::Simd, KernelPath::Compacted, KernelPath::Auto]
                    .into_iter()
                    .all(|path| {
                        let plan = KernelPlan::with_path(path).with_cutover(*cutover);
                        bits(plan.forward(&args)) == scalar
                    })
            })
        },
    );
}

/// Table CSV never changes row/column counts.
#[test]
fn prop_table_csv_rectangular() {
    forall(
        6,
        128,
        &FnGen(|rng: &mut Xoshiro256| {
            let cols = 1 + rng.gen_range(5);
            let rows = rng.gen_range(20);
            (cols, rows)
        }),
        |&(cols, rows)| {
            let names: Vec<String> = (0..cols).map(|c| format!("c{c}")).collect();
            let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
            let mut t = Table::new("t", &name_refs);
            for r in 0..rows {
                t.row((0..cols).map(|c| format!("{r},{c}")).collect());
            }
            let csv = t.to_csv();
            csv.lines().count() == rows + 1
                && csv.lines().all(|l| {
                    // cells containing commas are quoted; count unquoted commas
                    let mut in_q = false;
                    let mut commas = 0;
                    for ch in l.chars() {
                        match ch {
                            '"' => in_q = !in_q,
                            ',' if !in_q => commas += 1,
                            _ => {}
                        }
                    }
                    commas == cols - 1
                })
        },
    );
}
