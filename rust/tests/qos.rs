//! QoS end-to-end gates: typed load shedding over TCP, exactly-once
//! expiry accounting, priority-lane policy, the v2 degrade contract on
//! the wire, and the traffic-replay chaos harness.
//!
//! The overload tests make shedding *deterministic* by sizing the
//! admission lanes down to zero (an empty lane is full by definition),
//! so no assertion here depends on winning a timing race.

use catwalk::coordinator::pool::par_map;
use catwalk::proto::frame::{self, FrameType};
use catwalk::proto::{Outcome, Request};
use catwalk::qos::replay::{self, ChaosOptions, ReplayLog, ReplayOptions, SynthSpec};
use catwalk::qos::QosConfig;
use catwalk::registry::{ModelRegistry, ModelSpec, RegistryConfig};
use catwalk::server::{FramedClient, Server};
use catwalk::volley::SpikeVolley;
use catwalk::Error;
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

const N: usize = 16;

fn boot(qos: QosConfig) -> (Arc<Server>, String, std::thread::JoinHandle<()>) {
    let cfg = RegistryConfig {
        qos,
        ..RegistryConfig::default()
    };
    let spec = ModelSpec {
        n: N,
        theta: 6.0,
        seed: 7,
    };
    let registry = Arc::new(ModelRegistry::open(cfg, "default", spec).unwrap());
    let server = Arc::new(Server::with_registry(registry));
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    (server, addr, srv)
}

fn stop(server: &Server, srv: std::thread::JoinHandle<()>) {
    server
        .stop_handle()
        .store(true, std::sync::atomic::Ordering::Release);
    srv.join().unwrap();
}

fn silent() -> SpikeVolley {
    SpikeVolley::dense(vec![16.0; N])
}

/// A zero-depth infer lane sheds every request with the typed BUSY
/// reply carrying the configured retry hint — fast, no queue slot, no
/// compute — while PING/STATS (not admission-gated) keep working, and
/// the shed shows up in the `requests_shed` counter, aggregate and
/// per-model.
#[test]
fn zero_depth_gate_sheds_with_typed_busy() {
    let qos = QosConfig {
        infer_depth: 0,
        learn_depth: 0,
        retry_after_ms: 40,
        ..QosConfig::on()
    };
    let (server, addr, srv) = boot(qos);
    let mut client = FramedClient::connect(&addr).unwrap();

    for _ in 0..3 {
        let resp = client.call(Request::infer(vec![silent()])).unwrap();
        match resp.outcome {
            Outcome::Busy { retry_after_ms } => assert_eq!(retry_after_ms, 40),
            other => panic!("expected Busy, got {other:?}"),
        }
        // the ergonomic accessor surfaces it as the typed error
        let resp = client.call(Request::infer(vec![silent()])).unwrap();
        match resp.results() {
            Err(Error::Busy { retry_after_ms }) => assert_eq!(retry_after_ms, 40),
            other => panic!("{other:?}"),
        }
    }
    client.ping().unwrap();
    let s = client.stats().unwrap();
    assert_eq!(s.counter("requests_shed"), 6);
    assert_eq!(s.counter("model.default.requests_shed"), 6);
    assert_eq!(s.counter("model.default.requests"), 0, "nothing admitted");
    assert_eq!(s.counter("model.default.batches"), 0, "nothing executed");

    client.quit().unwrap();
    stop(&server, srv);
}

/// Overload acceptance gate: flood a depth-1 lane from many pipelined
/// connections; every request gets exactly one reply, every reply is
/// typed (Results or Busy, nothing else, no silent drops), and the
/// server-side ledger reconciles exactly: admitted + shed == sent.
#[test]
fn flood_gets_exactly_one_typed_reply_per_request() {
    let qos = QosConfig {
        infer_depth: 1,
        ..QosConfig::on()
    };
    let (server, addr, srv) = boot(qos);

    let conns = 8usize;
    let per_conn = 32usize;
    let barrier = Arc::new(Barrier::new(conns));
    let tallies: Vec<(u64, u64)> = par_map(conns, (0..conns).collect(), |_| {
        let mut client = FramedClient::connect(&addr).expect("connect");
        barrier.wait();
        let reqs: Vec<Request> = (0..per_conn)
            .map(|_| Request::infer(vec![silent()]))
            .collect();
        let resps = client.call_many(reqs).expect("call_many");
        assert_eq!(resps.len(), per_conn, "exactly one reply per request");
        let (mut ok, mut busy) = (0u64, 0u64);
        for resp in &resps {
            match &resp.outcome {
                Outcome::Results(rs) => {
                    assert_eq!(rs.len(), 1);
                    ok += 1;
                }
                Outcome::Busy { retry_after_ms } => {
                    assert!(*retry_after_ms >= 1);
                    busy += 1;
                }
                other => panic!("untyped reply under flood: {other:?}"),
            }
        }
        let _ = client.quit();
        (ok, busy)
    });

    let sent = (conns * per_conn) as u64;
    let ok: u64 = tallies.iter().map(|t| t.0).sum();
    let busy: u64 = tallies.iter().map(|t| t.1).sum();
    assert_eq!(ok + busy, sent, "no silent drops");
    assert!(
        busy > 0,
        "a depth-1 lane under 8 simultaneous connections must shed"
    );
    assert!(ok > 0, "the lane still serves while shedding");

    // server-side ledger: every volley is either admitted or shed,
    // counted exactly once
    let mut client = FramedClient::connect(&addr).unwrap();
    let s = client.stats().unwrap();
    assert_eq!(s.counter("model.default.requests"), ok);
    assert_eq!(s.counter("model.default.requests_shed"), busy);
    client.quit().unwrap();
    stop(&server, srv);
}

/// The silent-expiry regression pin: a request already past its
/// deadline at dispatch is answered with the typed error AND counted in
/// `requests_expired` exactly once, with the submit-side counters
/// mirrored so `requests >= requests_expired` stays an invariant.
#[test]
fn dispatch_expiry_counted_exactly_once() {
    let (server, addr, srv) = boot(QosConfig::default());
    let mut client = FramedClient::connect(&addr).unwrap();

    let doomed = Request::infer(vec![silent(), silent(), silent()]).with_deadline_ms(0);
    match client.call(doomed).unwrap().outcome {
        Outcome::Error(e) => assert!(e.contains("deadline"), "{e}"),
        other => panic!("{other:?}"),
    }
    let s = client.stats().unwrap();
    assert_eq!(
        s.counter("model.default.requests_expired"),
        3,
        "3 volleys expired once each — not zero (silent), not double"
    );
    assert_eq!(s.counter("model.default.requests"), 3);
    assert_eq!(s.counter("model.default.batches"), 0, "no kernel execution");

    // once more: the count advances by exactly the volley count again
    let doomed = Request::infer(vec![silent()]).with_deadline_ms(0);
    assert!(matches!(
        client.call(doomed).unwrap().outcome,
        Outcome::Error(_)
    ));
    let s = client.stats().unwrap();
    assert_eq!(s.counter("model.default.requests_expired"), 4);

    client.quit().unwrap();
    stop(&server, srv);
}

/// Priority lanes end-to-end: with the learn lane sized to zero, learn
/// traffic sheds with the typed BUSY (and lands in the shed counter)
/// while infer traffic on the same model is untouched — the lane
/// policy's guarantee that background learning cannot starve serving.
#[test]
fn learn_lane_sheds_while_infer_serves() {
    let qos = QosConfig {
        learn_depth: 0,
        ..QosConfig::on()
    };
    let (server, addr, srv) = boot(qos);
    let mut client = FramedClient::connect(&addr).unwrap();

    for _ in 0..4 {
        match client.call(Request::learn(vec![silent()])).unwrap().outcome {
            Outcome::Busy { .. } => {}
            other => panic!("learn should shed, got {other:?}"),
        }
        let resp = client.call(Request::infer(vec![silent()])).unwrap();
        assert_eq!(resp.results().unwrap().len(), 1, "infer unaffected");
    }
    let s = client.stats().unwrap();
    assert_eq!(s.counter("model.default.requests_shed"), 4);
    assert_eq!(s.counter("model.default.requests"), 4);

    client.quit().unwrap();
    stop(&server, srv);
}

/// The v2 degrade contract on the wire: a connection that negotiated
/// version 2 never receives the status-6 BUSY byte — a shed reply
/// arrives as the generic ERROR status carrying the rendered
/// `Error::Busy` message, so a pre-PR client decodes it fine.
#[test]
fn v2_connection_never_sees_status_busy() {
    let qos = QosConfig {
        infer_depth: 0,
        ..QosConfig::on()
    };
    let (server, addr, srv) = boot(qos);

    let mut stream = TcpStream::connect(&addr).unwrap();
    frame::write_frame(&mut stream, FrameType::Hello, &frame::encode_hello(2, 2)).unwrap();
    stream.flush().unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (ty, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ty, FrameType::Ack);
    assert_eq!(frame::decode_ack(&payload).unwrap().version, 2);

    for _ in 0..3 {
        let req = Request::infer(vec![silent()]).with_id(77);
        frame::write_frame(
            &mut stream,
            FrameType::Request,
            &frame::encode_request(&req).unwrap(),
        )
        .unwrap();
        stream.flush().unwrap();
        let (_, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
        // byte 8 of a response payload is the status: must be 4
        // (ERROR), never 6 (BUSY) on this connection
        assert_eq!(payload[8], 4, "v2 peer got status {}", payload[8]);
        let resp = frame::decode_response(&payload).unwrap();
        assert_eq!(resp.id, 77);
        match resp.outcome {
            Outcome::Error(e) => {
                assert!(e.contains("server busy"), "{e}");
                assert!(e.contains("retry after"), "{e}");
            }
            other => panic!("{other:?}"),
        }
    }

    // the same shed on a v3 client IS the structural status
    let mut v3 = FramedClient::connect(&addr).unwrap();
    assert!(matches!(
        v3.call(Request::infer(vec![silent()])).unwrap().outcome,
        Outcome::Busy { .. }
    ));
    v3.quit().unwrap();
    stop(&server, srv);
}

/// Replay log + live replay: synthesize a deterministic stream, save
/// and re-read it bitwise, replay it against a QoS server at 2x, and
/// check the client-side ledger covers every request with a typed
/// outcome.
#[test]
fn replay_log_roundtrips_and_replays_with_full_accounting() {
    let spec = SynthSpec {
        requests: 64,
        rate_per_s: 2000.0,
        n: N,
        t_max: 16,
        deadline_ms: Some(2_000),
        models: vec![String::new()],
        seed: 13,
    };
    let log = ReplayLog::synthesize(&spec);
    assert_eq!(log.entries.len(), 64);

    let dir = std::env::temp_dir().join(format!("catwalk-replay-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream.cwkr");
    log.save(&path).unwrap();
    let back = ReplayLog::read(&path).unwrap();
    assert_eq!(back.entries.len(), log.entries.len());
    for (a, b) in log.entries.iter().zip(&back.entries) {
        assert_eq!(a.offset_us, b.offset_us);
        assert_eq!(a.req, b.req);
    }

    let (server, addr, srv) = boot(QosConfig::on());
    let opts = ReplayOptions {
        multiple: 2.0,
        conns: 4,
    };
    let report = replay::replay(&addr, &log, &opts).unwrap();
    assert_eq!(report.sent, 64);
    assert_eq!(report.transport_errors, 0, "no torn connections");
    assert_eq!(
        report.answered(),
        report.sent,
        "every request got exactly one typed reply"
    );
    assert!(report.results > 0);
    assert!(report.percentile_us(99.0) >= report.percentile_us(50.0));

    std::fs::remove_dir_all(&dir).ok();
    stop(&server, srv);
}

/// The chaos acceptance gate: replay under stalled clients, a killed
/// shard slot and a corrupted checkpoint. Every contract must hold —
/// typed errors only, no hangs, the corrupt checkpoint is refused, and
/// the old weights keep serving bit-identical replies.
#[test]
fn chaos_replay_contracts_hold() {
    let scratch = std::env::temp_dir().join(format!("catwalk-chaos-t-{}", std::process::id()));
    let opts = ChaosOptions {
        artifacts_dir: "artifacts".into(),
        scratch_dir: scratch,
        spec: SynthSpec {
            requests: 48,
            rate_per_s: 1200.0,
            n: N,
            t_max: 16,
            deadline_ms: Some(2_000),
            models: vec![String::new()],
            seed: 21,
        },
        replay: ReplayOptions {
            multiple: 1.0,
            conns: 4,
        },
        qos: QosConfig::on(),
        stall_clients: 2,
        dist: false,
    };
    let report = replay::chaos_run(&opts).unwrap();
    assert_eq!(report.replay.transport_errors, 0);
    assert_eq!(report.replay.answered(), report.replay.sent);
    assert_eq!(report.victim_hangs, 0, "killed shard degrades, never hangs");
    assert!(report.victim_typed_errors > 0, "killed shard answers typed");
    assert!(report.corrupt_load_rejected, "corrupt checkpoint refused");
    assert!(report.weights_bit_identical, "old weights keep serving");
    assert!(report.survivor_serving);
    assert!(!report.shard_host_killed, "dist fault was not requested");
    assert!(report.contracts_hold());
}

/// The distributed chaos gate (`--chaos --dist`): on top of the local
/// faults, a remote 2-shard model loses a shard *host* mid-traffic.
/// The kill window must resolve every probe as a typed error inside
/// the bounded client timeouts (never a hang), and failover onto the
/// replicated standby must resume the committed checkpoint generation
/// bit-identically — the post-commit learns roll back like a crash.
#[test]
fn chaos_dist_killed_shard_host_contracts_hold() {
    let scratch = std::env::temp_dir().join(format!("catwalk-chaos-d-{}", std::process::id()));
    let opts = ChaosOptions {
        artifacts_dir: "artifacts".into(),
        scratch_dir: scratch,
        spec: SynthSpec {
            requests: 24,
            rate_per_s: 1200.0,
            n: N,
            t_max: 16,
            deadline_ms: Some(2_000),
            models: vec![String::new()],
            seed: 33,
        },
        replay: ReplayOptions {
            multiple: 2.0,
            conns: 2,
        },
        qos: QosConfig::on(),
        stall_clients: 1,
        dist: true,
    };
    let report = replay::chaos_run(&opts).unwrap();
    assert!(report.shard_host_killed, "the dist fault ran");
    assert_eq!(report.dist_hangs, 0, "killed host degrades, never hangs");
    assert!(
        report.dist_typed_errors > 0,
        "the kill window surfaced typed errors"
    );
    assert!(report.failover_recovered, "standby took the dead slice over");
    assert!(
        report.failover_weights_match,
        "failover resumed the committed generation bit-identically"
    );
    assert!(report.contracts_hold());
}
