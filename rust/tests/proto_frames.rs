//! v2 framed-protocol conformance: golden byte vectors (shared with the
//! python wire twin), quickprop round-trip properties over random
//! envelopes, malformed-frame typed errors, and TCP end-to-end proof
//! that the framed path is bit-identical to the text path.

use catwalk::coordinator::{BatcherConfig, DynamicBatcher, TnnHandle};
use catwalk::proto::frame::{self, FrameType};
use catwalk::proto::{
    AdminReply, HistStats, ModelCmd, ModelInfo, Op, Outcome, Request, RequestOpts, Response,
    StatsSnapshot,
};
use catwalk::quickprop::{forall, FnGen};
use catwalk::rng::Xoshiro256;
use catwalk::server::{Client, FramedClient, Server};
use catwalk::volley::{SpikeVolley, VolleyResult};
use catwalk::Error;
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

const TM: usize = 16;

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

// ------------------------------------------------------- golden vectors

// The same constants appear in python/tests/test_proto_frames.py; they
// are the cross-language wire contract. If either side changes the
// layout, exactly one of the two suites breaks.
const GOLDEN_REQUEST_HEX: &str = "43574b32030000003600000000000000070103000000fa00020000000004\
3f8000004180000040200000418000000100000004000000010000000140400000";
const GOLDEN_RESPONSE_HEX: &str = "43574b32040000001f000000000000000700000100000002000000034080\
00004180000040000000";
const GOLDEN_HELLO_HEX: &str = "43574b32010000000400020002";
const GOLDEN_ACK_HEX: &str = "43574b32020000000e0002000000100000000800000010";

// v3 (model registry) golden vectors — also asserted in the python twin.
const GOLDEN_MODEL_REQUEST_HEX: &str = "43574b3203000000270000000000000007010800046564676500\
0100000000043f800000418000004020000041800000";
const GOLDEN_ADMIN_CREATE_HEX: &str = "43574b3203000000210000000000000008060002000465646765\
0000001040c000000000000000000005";
const GOLDEN_ADMIN_LIST_HEX: &str = "43574b32030000000b0000000000000009060001";
const GOLDEN_MODELS_RESPONSE_HEX: &str = "43574b32040000004d0000000000000009050100020007\
64656661756c7400000040000000100000001040c000000000000000000007010004656467650000001000000008\
0000001040c00000000000000000000500";
const GOLDEN_HELLO_V3_HEX: &str = "43574b32010000000400020003";
const GOLDEN_ACK_V3_HEX: &str = "43574b32020000000e0003000000400000001000000010";

// The QoS shed reply (status 6, v3-only; PR 7): id 7, retry 250 ms.
const GOLDEN_BUSY_RESPONSE_HEX: &str = "43574b32040000000d000000000000000706000000fa";

// The obs tier (v3-only; PR 9): a model-routed infer carrying a
// propagated trace id (flags = FLAG_MODEL | FLAG_TRACE, trace field
// between deadline and model), and the nullary FETCH_TRACE admin verb.
const GOLDEN_TRACE_REQUEST_HEX: &str = "43574b32030000002f0000000000000007012801020304050607\
08000465646765000100000000043f800000418000004020000041800000";
const GOLDEN_FETCH_TRACE_HEX: &str = "43574b32030000000b000000000000000c06000b";

// The telemetry plane (v3-only; PR 10): the nullary FETCH_METRICS /
// FETCH_HEALTH admin verbs — same envelope as FETCH_TRACE, cmd bytes
// 12 and 13.
const GOLDEN_FETCH_METRICS_HEX: &str = "43574b32030000000b000000000000000d06000c";
const GOLDEN_FETCH_HEALTH_HEX: &str = "43574b32030000000b000000000000000e06000d";

fn golden_request() -> Request {
    Request {
        id: 7,
        op: Op::Infer,
        volleys: vec![
            SpikeVolley::dense(vec![1.0, 16.0, 2.5, 16.0]),
            SpikeVolley::sparse(4, vec![(1, 3.0)], TM).unwrap(),
        ],
        gates: None,
        opts: RequestOpts {
            sparse_reply: true,
            deadline_ms: Some(250),
            counters_only: false,
            model: None,
            trace: None,
        },
    }
}

fn golden_trace_request() -> Request {
    Request::infer(vec![SpikeVolley::dense(vec![1.0, 16.0, 2.5, 16.0])])
        .with_id(7)
        .with_model("edge")
        .with_trace(0x0102_0304_0506_0708)
}

fn golden_model_request() -> Request {
    Request::infer(vec![SpikeVolley::dense(vec![1.0, 16.0, 2.5, 16.0])])
        .with_id(7)
        .with_model("edge")
}

fn golden_admin_create() -> Request {
    Request::admin(ModelCmd::Create {
        name: "edge".into(),
        n: 16,
        theta: 6.0,
        seed: 5,
    })
    .with_id(8)
}

fn golden_models_response() -> Response {
    Response {
        id: 9,
        outcome: Outcome::Admin(AdminReply::Models(vec![
            ModelInfo {
                name: "default".into(),
                n: 64,
                c: 16,
                t_max: 16,
                theta: 6.0,
                seed: 7,
                default: true,
            },
            ModelInfo {
                name: "edge".into(),
                n: 16,
                c: 8,
                t_max: 16,
                theta: 6.0,
                seed: 5,
                default: false,
            },
        ])),
    }
}

fn golden_response() -> Response {
    Response {
        id: 7,
        outcome: Outcome::Results(vec![VolleyResult {
            times: vec![4.0, 16.0, 2.0],
            winner: Some(2),
        }]),
    }
}

fn framed(ty: FrameType, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::new();
    frame::write_frame(&mut buf, ty, payload).unwrap();
    buf
}

#[test]
fn golden_request_bytes_match_python_twin() {
    let bytes = framed(
        FrameType::Request,
        &frame::encode_request(&golden_request()).unwrap(),
    );
    assert_eq!(hex(&bytes), GOLDEN_REQUEST_HEX);
    // and the bytes decode back to the exact envelope
    let (ty, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(ty, FrameType::Request);
    assert_eq!(frame::decode_request(&payload).unwrap(), golden_request());
}

#[test]
fn golden_response_bytes_match_python_twin() {
    let bytes = framed(
        FrameType::Response,
        &frame::encode_response(&golden_response()).unwrap(),
    );
    assert_eq!(hex(&bytes), GOLDEN_RESPONSE_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(frame::decode_response(&payload).unwrap(), golden_response());
}

#[test]
fn golden_handshake_bytes_match_python_twin() {
    assert_eq!(
        hex(&framed(FrameType::Hello, &frame::encode_hello(2, 2))),
        GOLDEN_HELLO_HEX
    );
    let ack = frame::Ack {
        version: 2,
        n: 16,
        c: 8,
        t_max: 16,
    };
    assert_eq!(
        hex(&framed(FrameType::Ack, &frame::encode_ack(&ack))),
        GOLDEN_ACK_HEX
    );
    // what a v3 client actually opens with, and the matching ACK
    assert_eq!(
        hex(&framed(
            FrameType::Hello,
            &frame::encode_hello(frame::MIN_VERSION, frame::VERSION)
        )),
        GOLDEN_HELLO_V3_HEX
    );
    let ack = frame::Ack {
        version: 3,
        n: 64,
        c: 16,
        t_max: 16,
    };
    assert_eq!(
        hex(&framed(FrameType::Ack, &frame::encode_ack(&ack))),
        GOLDEN_ACK_V3_HEX
    );
}

#[test]
fn golden_v3_bytes_match_python_twin() {
    let bytes = framed(
        FrameType::Request,
        &frame::encode_request(&golden_model_request()).unwrap(),
    );
    assert_eq!(hex(&bytes), GOLDEN_MODEL_REQUEST_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(
        frame::decode_request(&payload).unwrap(),
        golden_model_request()
    );

    let bytes = framed(
        FrameType::Request,
        &frame::encode_request(&golden_admin_create()).unwrap(),
    );
    assert_eq!(hex(&bytes), GOLDEN_ADMIN_CREATE_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(
        frame::decode_request(&payload).unwrap(),
        golden_admin_create()
    );

    let list = Request::admin(ModelCmd::List).with_id(9);
    let bytes = framed(FrameType::Request, &frame::encode_request(&list).unwrap());
    assert_eq!(hex(&bytes), GOLDEN_ADMIN_LIST_HEX);

    // PR 9: the propagated trace id rides between deadline and model
    let bytes = framed(
        FrameType::Request,
        &frame::encode_request(&golden_trace_request()).unwrap(),
    );
    assert_eq!(hex(&bytes), GOLDEN_TRACE_REQUEST_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(
        frame::decode_request(&payload).unwrap(),
        golden_trace_request()
    );

    // PR 9: the nullary FETCH_TRACE admin verb
    let fetch = Request::admin(ModelCmd::FetchTrace).with_id(12);
    let bytes = framed(FrameType::Request, &frame::encode_request(&fetch).unwrap());
    assert_eq!(hex(&bytes), GOLDEN_FETCH_TRACE_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(frame::decode_request(&payload).unwrap(), fetch);

    // PR 10: the nullary telemetry admin verbs
    let fetch = Request::admin(ModelCmd::FetchMetrics).with_id(13);
    let bytes = framed(FrameType::Request, &frame::encode_request(&fetch).unwrap());
    assert_eq!(hex(&bytes), GOLDEN_FETCH_METRICS_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(frame::decode_request(&payload).unwrap(), fetch);

    let fetch = Request::admin(ModelCmd::FetchHealth).with_id(14);
    let bytes = framed(FrameType::Request, &frame::encode_request(&fetch).unwrap());
    assert_eq!(hex(&bytes), GOLDEN_FETCH_HEALTH_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(frame::decode_request(&payload).unwrap(), fetch);

    let bytes = framed(
        FrameType::Response,
        &frame::encode_response(&golden_models_response()).unwrap(),
    );
    assert_eq!(hex(&bytes), GOLDEN_MODELS_RESPONSE_HEX);
    let (_, payload) = frame::read_frame(&mut &bytes[..]).unwrap().unwrap();
    assert_eq!(
        frame::decode_response(&payload).unwrap(),
        golden_models_response()
    );
}

/// The BUSY status frame: golden bytes shared with the python twin, a
/// lossless decode back, truncation at every cut is a typed error, and
/// the v2 degrade renders the same retry hint through the generic
/// ERROR status instead.
#[test]
fn golden_busy_bytes_match_python_twin() {
    let resp = Response::busy(7, 250);
    let payload = frame::encode_response(&resp).unwrap();
    let bytes = framed(FrameType::Response, &payload);
    assert_eq!(hex(&bytes), GOLDEN_BUSY_RESPONSE_HEX);
    assert_eq!(frame::decode_response(&payload).unwrap(), resp);
    // status byte sits right after the u64 id
    assert_eq!(payload[8], 6);
    // any truncation of the 13-byte payload is a typed error
    for cut in 0..payload.len() {
        assert!(
            matches!(frame::decode_response(&payload[..cut]), Err(Error::Proto(_))),
            "cut at {cut} must be a typed error"
        );
    }
    // the v2 fallback form: same envelope id, generic ERROR status,
    // retry hint preserved in the rendered message
    let degraded = Response::busy(7, 250).degrade_busy();
    assert_eq!(degraded.id, 7);
    let payload = frame::encode_response(&degraded).unwrap();
    assert_eq!(payload[8], 4, "v2 form uses the ERROR status");
    match degraded.outcome {
        Outcome::Error(e) => assert_eq!(e, "server busy, retry after 250 ms"),
        other => panic!("{other:?}"),
    }
    // non-busy outcomes pass through degrade untouched
    let ok = golden_response().degrade_busy();
    assert_eq!(ok, golden_response());
}

// ----------------------------------------------------------- properties

fn gen_volley(rng: &mut Xoshiro256) -> SpikeVolley {
    let n = 1 + rng.gen_range(48);
    if rng.gen_bool(0.5) {
        // dense, any finite times (incl. non-canonical silence)
        SpikeVolley::dense((0..n).map(|_| (rng.gen_f64() * 24.0) as f32).collect())
    } else {
        let nnz = rng.gen_range(n + 1);
        let mut lines = rng.sample_indices(n, nnz);
        lines.sort_unstable();
        let spikes: Vec<(usize, f32)> = lines
            .into_iter()
            .map(|l| (l, (rng.gen_f64() * (TM as f64 - 0.5)) as f32))
            .collect();
        SpikeVolley::sparse(n, spikes, TM).unwrap()
    }
}

/// Frame codec round-trip is the identity over random envelopes —
/// every op, every flag combination, dense and sparse volleys mixed.
#[test]
fn prop_request_roundtrip_lossless() {
    forall(
        11,
        256,
        &FnGen(|rng: &mut Xoshiro256| {
            let ops = [Op::Infer, Op::Learn, Op::Stats, Op::Ping, Op::Quit];
            let nv = rng.gen_range(5);
            let op = ops[rng.gen_range(ops.len())].clone();
            // gates ride LEARN only (the codec refuses them elsewhere)
            let gates = if matches!(op, Op::Learn) && rng.gen_bool(0.5) {
                Some(
                    (0..rng.gen_range(24))
                        .map(|_| if rng.gen_bool(0.5) { 1.0 } else { 0.0 })
                        .collect(),
                )
            } else {
                None
            };
            Request {
                id: rng.next_u64(),
                op,
                volleys: (0..nv).map(|_| gen_volley(rng)).collect(),
                gates,
                opts: RequestOpts {
                    sparse_reply: rng.gen_bool(0.5),
                    deadline_ms: if rng.gen_bool(0.5) {
                        Some(rng.next_u32())
                    } else {
                        None
                    },
                    counters_only: rng.gen_bool(0.5),
                    model: if rng.gen_bool(0.5) {
                        Some(format!("m{}", rng.gen_range(1000)))
                    } else {
                        None
                    },
                    trace: if rng.gen_bool(0.5) {
                        Some(rng.next_u64())
                    } else {
                        None
                    },
                },
            }
        }),
        |req| {
            let enc = frame::encode_request(req).unwrap();
            frame::decode_request(&enc).unwrap() == *req
        },
    );
}

/// Response round-trip over random results, stats and errors.
#[test]
fn prop_response_roundtrip_lossless() {
    forall(
        12,
        256,
        &FnGen(|rng: &mut Xoshiro256| {
            let outcome = match rng.gen_range(5) {
                0 => Outcome::Results(
                    (0..rng.gen_range(4))
                        .map(|_| {
                            let c = 1 + rng.gen_range(16);
                            VolleyResult {
                                times: (0..c).map(|_| (rng.gen_f64() * 16.0) as f32).collect(),
                                winner: if rng.gen_bool(0.5) {
                                    Some(rng.gen_range(c))
                                } else {
                                    None
                                },
                            }
                        })
                        .collect(),
                ),
                1 => {
                    let mut s = StatsSnapshot::new();
                    for i in 0..rng.gen_range(6) {
                        s.counters.insert(format!("c{i}"), rng.next_u64());
                    }
                    for i in 0..rng.gen_range(3) {
                        s.hists.insert(
                            format!("h{i}"),
                            HistStats {
                                count: rng.next_u64() % 1_000_000,
                                mean_us: rng.gen_f64() * 1e6,
                                p50_us: rng.next_u64() % 1_000_000,
                                p95_us: rng.next_u64() % 1_000_000,
                                p99_us: rng.next_u64() % 1_000_000,
                                max_us: rng.next_u64() % 1_000_000,
                            },
                        );
                    }
                    Outcome::Stats(s)
                }
                2 => Outcome::Pong,
                3 => Outcome::Bye,
                _ => Outcome::Error(format!("err {} ✗", rng.next_u32())),
            };
            Response {
                id: rng.next_u64(),
                outcome,
            }
        }),
        |resp| {
            let enc = frame::encode_response(resp).unwrap();
            frame::decode_response(&enc).unwrap() == *resp
        },
    );
}

/// Any truncation of a valid request payload is a typed error, never a
/// panic or a silent misparse.
#[test]
fn prop_truncated_request_is_typed_error() {
    forall(
        13,
        64,
        &FnGen(|rng: &mut Xoshiro256| {
            let req = Request {
                id: rng.next_u64(),
                op: Op::Infer,
                volleys: (0..1 + rng.gen_range(3)).map(|_| gen_volley(rng)).collect(),
                gates: None,
                opts: RequestOpts::default(),
            };
            let enc = frame::encode_request(&req).unwrap();
            let cut = rng.gen_range(enc.len());
            enc[..cut].to_vec()
        }),
        |prefix| {
            matches!(frame::decode_request(prefix), Err(Error::Proto(_)))
        },
    );
}

/// Admin envelopes round-trip losslessly over the frame codec.
#[test]
fn prop_admin_roundtrip_lossless() {
    forall(
        14,
        128,
        &FnGen(|rng: &mut Xoshiro256| {
            let name = format!("m{}", rng.gen_range(10_000));
            let blob = |rng: &mut Xoshiro256| -> Vec<u8> {
                (0..rng.gen_range(64)).map(|_| rng.next_u32() as u8).collect()
            };
            let cmd = match rng.gen_range(13) {
                0 => ModelCmd::List,
                10 => ModelCmd::FetchTrace,
                11 => ModelCmd::FetchMetrics,
                12 => ModelCmd::FetchHealth,
                1 => ModelCmd::Create {
                    name,
                    n: 1 + rng.gen_range(256),
                    theta: (rng.gen_f64() * 20.0) as f32,
                    seed: rng.next_u64(),
                },
                2 => ModelCmd::Save { name },
                3 => ModelCmd::Load { name },
                4 => ModelCmd::Unload { name },
                5 => {
                    let start = rng.gen_range(64);
                    ModelCmd::CreateColumns {
                        name,
                        index: rng.gen_range(16),
                        n: 1 + rng.gen_range(256),
                        theta: (rng.gen_f64() * 20.0) as f32,
                        seed: rng.next_u64(),
                        start,
                        end: start + 1 + rng.gen_range(64),
                    }
                }
                6 => ModelCmd::FetchCkpt { name },
                7 => ModelCmd::PutCkpt {
                    name,
                    bytes: blob(rng),
                },
                8 => ModelCmd::PutShard {
                    name,
                    index: rng.gen_range(16),
                    crc: rng.next_u32(),
                    bytes: blob(rng),
                },
                _ => ModelCmd::PutManifest {
                    name,
                    bytes: blob(rng),
                },
            };
            Request::admin(cmd).with_id(rng.next_u64())
        }),
        |req| {
            let enc = frame::encode_request(req).unwrap();
            frame::decode_request(&enc).unwrap() == *req
        },
    );
}

// ------------------------------------------------------------ TCP tests

fn boot(n: usize, seed: u64) -> (Arc<Server>, String, std::thread::JoinHandle<()>) {
    let handle = TnnHandle::open("artifacts", n, 6.0, seed).unwrap();
    let server = Arc::new(Server::new(handle, BatcherConfig::default()));
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    (server, addr, srv)
}

fn stop(server: &Server, srv: std::thread::JoinHandle<()>) {
    server
        .stop_handle()
        .store(true, std::sync::atomic::Ordering::Release);
    srv.join().unwrap();
}

/// Acceptance gate: for the same volleys, the v2 framed path and the
/// legacy text path return bit-identical winners and times — and the
/// two codecs coexist on one port.
#[test]
fn framed_results_bit_identical_to_text_path() {
    let n = 16;
    let (server, addr, srv) = boot(n, 33);
    let mut text = Client::connect(&addr).unwrap();
    let mut framed = FramedClient::connect(&addr).unwrap();
    assert_eq!(framed.version, frame::VERSION);
    assert_eq!((framed.n, framed.c, framed.t_max), (16, 8, 16));

    let mut rng = Xoshiro256::new(909);
    for _ in 0..25 {
        let volley: Vec<f32> = (0..n)
            .map(|_| {
                if rng.gen_bool(0.35) {
                    rng.gen_range(8) as f32
                } else {
                    16.0
                }
            })
            .collect();
        let (tw, tt) = text.infer(&volley).unwrap();
        let (fw, ft) = framed.infer(&volley).unwrap();
        assert_eq!(tw, fw, "winner diverges for {volley:?}");
        assert_eq!(tt, ft, "times diverge for {volley:?}");
        // sparse request encoding through the frame codec too
        let sparse = SpikeVolley::dense(volley.clone()).to_sparse(framed.t_max);
        let fr = framed.infer_batch(vec![sparse]).unwrap();
        assert_eq!(fr[0].times, tt);
        assert_eq!(fr[0].winner, if fw < 0 { None } else { Some(fw as usize) });
    }

    text.quit().unwrap();
    framed.quit().unwrap();
    stop(&server, srv);
}

/// Pipelining: N requests written before any response is read; ids
/// echo back in order and results match the sequential path.
#[test]
fn framed_pipelining_and_multi_volley_batches() {
    let n = 16;
    let (server, addr, srv) = boot(n, 34);
    let mut framed = FramedClient::connect(&addr).unwrap();

    let mut rng = Xoshiro256::new(11);
    let volleys: Vec<Vec<f32>> = (0..24)
        .map(|_| {
            (0..n)
                .map(|_| {
                    if rng.gen_bool(0.3) {
                        rng.gen_range(8) as f32
                    } else {
                        16.0
                    }
                })
                .collect()
        })
        .collect();

    // sequential reference
    let mut seq = Vec::new();
    for v in &volleys {
        seq.push(framed.infer(v).unwrap());
    }

    // pipelined: one flush, 24 in-flight requests
    let reqs: Vec<Request> = volleys
        .iter()
        .map(|v| Request::infer(vec![SpikeVolley::dense(v.clone())]))
        .collect();
    let resps = framed.call_many(reqs).unwrap();
    assert_eq!(resps.len(), 24);
    for (resp, (w, t)) in resps.iter().zip(&seq) {
        let rs = resp.results().unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].winner.map(|x| x as i64).unwrap_or(-1), *w);
        assert_eq!(&rs[0].times, t);
    }
    // ids are strictly increasing and unique
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    let before = ids.clone();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 24);
    assert_eq!(before, ids, "responses arrive in request order");

    // one multi-volley frame == the same volleys one by one
    let batch: Vec<SpikeVolley> = volleys
        .iter()
        .map(|v| SpikeVolley::dense(v.clone()))
        .collect();
    let rs = framed.infer_batch(batch).unwrap();
    assert_eq!(rs.len(), 24);
    for (r, (w, t)) in rs.iter().zip(&seq) {
        assert_eq!(r.winner.map(|x| x as i64).unwrap_or(-1), *w);
        assert_eq!(&r.times, t);
    }

    framed.quit().unwrap();
    stop(&server, srv);
}

/// Envelope ops over both codecs: PING, typed STATS (full and
/// counters-only), deadline enforcement, and learn-path parity.
#[test]
fn envelope_ops_end_to_end() {
    let n = 16;
    let (server, addr, srv) = boot(n, 35);
    let mut framed = FramedClient::connect(&addr).unwrap();
    let mut text = Client::connect(&addr).unwrap();

    framed.ping().unwrap();
    let resp = text.call(&Request::op(Op::Ping)).unwrap();
    assert_eq!(resp.outcome, Outcome::Pong);

    // drive some traffic, then check the typed stats on both codecs
    let volley = vec![0.0f32; n];
    framed.infer(&volley).unwrap();
    framed.learn(&volley).unwrap();
    let s = framed.stats().unwrap();
    assert!(s.counter("requests") >= 2);
    assert!(s.counter("volleys_learned") >= 1);
    assert!(!s.hists.is_empty(), "full snapshot carries histograms");
    let ts = text.stats().unwrap();
    assert!(ts.counter("requests") >= 2);
    assert_eq!(
        ts.hist("request_latency").map(|h| h.count > 0),
        Some(true)
    );

    // counters-only stats opt
    let mut cheap = Request::op(Op::Stats);
    cheap.opts.counters_only = true;
    match framed.call(cheap).unwrap().outcome {
        Outcome::Stats(s) => assert!(s.hists.is_empty()),
        other => panic!("{other:?}"),
    }

    // a 0 ms deadline has always expired by dispatch time
    let doomed = Request::infer(vec![SpikeVolley::dense(volley.clone())]).with_deadline_ms(0);
    match framed.call(doomed).unwrap().outcome {
        Outcome::Error(e) => assert!(e.contains("deadline"), "{e}"),
        other => panic!("{other:?}"),
    }
    // ...and a generous one sails through
    let fine = Request::infer(vec![SpikeVolley::dense(volley.clone())])
        .with_deadline_ms(60_000);
    assert_eq!(framed.call(fine).unwrap().results().unwrap().len(), 1);

    // text multi-volley call pipelines one line per volley
    let resp = text
        .call(&Request::infer(vec![
            SpikeVolley::dense(vec![16.0; 16]),
            SpikeVolley::dense(vec![0.0; 16]),
        ]))
        .unwrap();
    let rs = resp.results().unwrap();
    assert_eq!(rs.len(), 2);
    assert_eq!(rs[0].winner, None);
    assert!(rs[1].winner.is_some());

    text.quit().unwrap();
    framed.quit().unwrap();
    stop(&server, srv);
}

/// The deadline opt bounds the batcher queue wait, not just decode
/// time: volleys still queued past their deadline are dropped with a
/// typed error at drain, and never cost a backend execution.
#[test]
fn batcher_drops_expired_requests_at_drain() {
    let handle = TnnHandle::open("artifacts", 16, 6.0, 40).unwrap();
    let metrics = handle.metrics.clone();
    // max_batch = 2 drains the queue the moment both volleys are in, so
    // the test never depends on the (long) flush timer
    let batcher = DynamicBatcher::start(
        handle,
        BatcherConfig {
            max_batch: 2,
            flush_after: Duration::from_secs(30),
            learn: false,
        },
    );
    let volleys = || vec![SpikeVolley::dense(vec![16.0; 16]), SpikeVolley::dense(vec![16.0; 16])];

    let expired = Instant::now() - Duration::from_millis(1);
    for r in batcher.submit_many_with_deadline(volleys(), Some(expired)) {
        let e = r.unwrap_err().to_string();
        assert!(e.contains("deadline"), "{e}");
    }
    assert_eq!(metrics.counter("requests_expired"), 2);
    assert_eq!(metrics.counter("batches"), 0, "no backend execution");

    // a generous deadline sails through on the same batcher
    let live = Instant::now() + Duration::from_secs(60);
    for r in batcher.submit_many_with_deadline(volleys(), Some(live)) {
        assert_eq!(r.unwrap().times.len(), 8);
    }
    assert_eq!(metrics.counter("batches"), 1);
}

/// Version negotiation and hostile frames against a live server: typed
/// rejections, and a malformed request payload does not poison the
/// connection.
#[test]
fn negotiation_and_hostile_frames_over_tcp() {
    let n = 16;
    let (server, addr, srv) = boot(n, 36);

    // a client that only speaks a future version is rejected in kind
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        frame::write_frame(
            &mut stream,
            FrameType::Hello,
            &frame::encode_hello(9, 12),
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let (ty, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(ty, FrameType::Response);
        let resp = frame::decode_response(&payload).unwrap();
        match resp.outcome {
            Outcome::Error(e) => assert!(e.contains("no common protocol version"), "{e}"),
            other => panic!("{other:?}"),
        }
    }
    // FramedClient surfaces the same rejection as a typed error
    // (negotiate() is pinned to VERSION, so only a matching range works)

    // malformed request payload inside an intact frame: typed error
    // response (id 0), then the connection still serves good requests
    {
        let mut framed = FramedClient::connect(&addr).unwrap();
        // craft garbage through the raw writer path: a valid frame whose
        // payload is one hostile byte
        let mut stream = TcpStream::connect(&addr).unwrap();
        frame::write_frame(
            &mut stream,
            FrameType::Hello,
            &frame::encode_hello(2, 2),
        )
        .unwrap();
        stream.flush().unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let (ty, _) = frame::read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(ty, FrameType::Ack);
        frame::write_frame(&mut stream, FrameType::Request, &[0xFF]).unwrap();
        stream.flush().unwrap();
        let (_, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
        let resp = frame::decode_response(&payload).unwrap();
        assert_eq!(resp.id, 0);
        assert!(matches!(resp.outcome, Outcome::Error(_)));
        // same connection, now a well-formed request
        frame::write_frame(
            &mut stream,
            FrameType::Request,
            &frame::encode_request(&Request::infer(vec![SpikeVolley::dense(vec![
                16.0;
                16
            ])]).with_id(5))
            .unwrap(),
        )
        .unwrap();
        stream.flush().unwrap();
        let (_, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
        let resp = frame::decode_response(&payload).unwrap();
        assert_eq!(resp.id, 5);
        assert_eq!(resp.results().unwrap().len(), 1);

        framed.quit().unwrap();
    }

    stop(&server, srv);
}

/// Back-compat acceptance gate: a pre-PR v2 client (HELLO 2..2, no
/// model flag, no admin ops) negotiates version 2 against the registry
/// server and gets **byte-identical** response frames to a v3 client's
/// for the same default-model request — while a v3 client on the same
/// port negotiates 3 and may route by model.
#[test]
fn v2_negotiation_back_compat_gate() {
    let n = 16;
    let (server, addr, srv) = boot(n, 37);

    // the v3 side: negotiated version is 3
    let mut v3 = FramedClient::connect(&addr).unwrap();
    assert_eq!(v3.version, frame::VERSION);

    // the v2 side: raw frames exactly as a pre-PR build sent them
    let mut stream = TcpStream::connect(&addr).unwrap();
    frame::write_frame(&mut stream, FrameType::Hello, &frame::encode_hello(2, 2)).unwrap();
    stream.flush().unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (ty, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ty, FrameType::Ack);
    let ack = frame::decode_ack(&payload).unwrap();
    assert_eq!(ack.version, 2, "server honors the client's v2 ceiling");
    assert_eq!((ack.n as usize, ack.c as usize), (16, 8));

    // identical infer requests (same id, same volley, no v3 fields)
    // must produce identical response payloads on both connections
    let volley = vec![0.0f32; n];
    let req = Request::infer(vec![SpikeVolley::dense(volley.clone())]).with_id(41);
    let enc = frame::encode_request(&req).unwrap();
    // the encoding itself is the v2 layout: flags byte is 0
    assert_eq!(enc[9], 0);
    frame::write_frame(&mut stream, FrameType::Request, &enc).unwrap();
    stream.flush().unwrap();
    let (_, v2_payload) = frame::read_frame(&mut reader).unwrap().unwrap();

    let mut v3_payload = None;
    for resp in v3.call_many(vec![req.clone()]).unwrap() {
        assert_eq!(resp.id, 41);
        v3_payload = Some(frame::encode_response(&resp).unwrap());
    }
    assert_eq!(
        hex(&v2_payload),
        hex(&v3_payload.unwrap()),
        "default-model replies are byte-identical across negotiated versions"
    );

    // a v3-only construct on the v2 connection is refused by the
    // server with a typed error — the negotiated version is a
    // contract, not advice (and status-5 replies never reach a v2 peer)
    frame::write_frame(
        &mut stream,
        FrameType::Request,
        &frame::encode_request(&Request::admin(ModelCmd::List).with_id(43)).unwrap(),
    )
    .unwrap();
    stream.flush().unwrap();
    let (_, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
    let resp = frame::decode_response(&payload).unwrap();
    assert_eq!(resp.id, 43);
    match resp.outcome {
        Outcome::Error(e) => assert!(e.contains("v3"), "{e}"),
        other => panic!("{other:?}"),
    }
    // ...and so is a model-routed request on the same v2 connection
    frame::write_frame(
        &mut stream,
        FrameType::Request,
        &frame::encode_request(
            &Request::infer(vec![SpikeVolley::dense(volley.clone())])
                .with_id(44)
                .with_model("default"),
        )
        .unwrap(),
    )
    .unwrap();
    stream.flush().unwrap();
    let (_, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
    let resp = frame::decode_response(&payload).unwrap();
    assert!(matches!(resp.outcome, Outcome::Error(_)));

    // on the v3 connection the same constructs work
    let (w, _) = v3.infer_model("default", &volley).unwrap();
    let (w2, _) = v3.infer(&volley).unwrap();
    assert_eq!(w, w2, "explicit default-model routing matches unrouted");

    // v2 connection closes politely
    frame::write_frame(
        &mut stream,
        FrameType::Request,
        &frame::encode_request(&Request::op(Op::Quit).with_id(1)).unwrap(),
    )
    .unwrap();
    stream.flush().unwrap();
    let (_, payload) = frame::read_frame(&mut reader).unwrap().unwrap();
    assert!(matches!(
        frame::decode_response(&payload).unwrap().outcome,
        Outcome::Bye
    ));

    v3.quit().unwrap();
    stop(&server, srv);
}

/// A client that negotiated v2 must not be able to send v3 constructs:
/// the client refuses locally with a typed error (the server would
/// reject the bytes otherwise). Simulated by forcing the version down,
/// since a real server always offers v3.
#[test]
fn v3_constructs_refused_on_v2_connection() {
    let (server, addr, srv) = boot(16, 38);
    let mut client = FramedClient::connect(&addr).unwrap();
    client.version = 2; // as if the peer had capped the handshake
    let err = client
        .call(Request::infer(vec![SpikeVolley::dense(vec![0.0; 16])]).with_model("edge"))
        .unwrap_err();
    assert!(err.to_string().contains("cannot carry"), "{err}");
    let err = client.models().unwrap_err();
    assert!(err.to_string().contains("cannot carry"), "{err}");
    // plain v2 requests still work on the same client afterwards
    let (_, times) = client.infer(&[16.0; 16]).unwrap();
    assert_eq!(times.len(), 8);
    client.quit().unwrap();
    stop(&server, srv);
}
