//! Cross-module integration tests: netlists vs behavioral models vs
//! selector semantics, end to end through the hardware substrate.

use catwalk::experiments::activity::{measure_neuron, StimulusConfig};
use catwalk::neuron::behavior::BehavioralNeuron;
use catwalk::neuron::stimulus::{VolleyGen, GAMMA_LEN};
use catwalk::neuron::{DendriteKind, NeuronConfig, NeuronDesign};
use catwalk::power::Estimator;
use catwalk::rng::Xoshiro256;
use catwalk::sim::{Simulator, Simulator64};
use catwalk::sorters::{CsNetwork, SorterKind};
use catwalk::topk::TopkSelector;

/// Every design at every paper size matches its behavioral golden model
/// cycle-for-cycle across many random volleys.
#[test]
fn all_designs_match_golden_model_at_all_sizes() {
    for kind in DendriteKind::ALL {
        for n in [16usize, 32, 64] {
            let cfg = NeuronConfig {
                n_inputs: n,
                k: 2,
                ..Default::default()
            };
            let design = NeuronDesign::build(kind, &cfg).unwrap();
            let mut sim = Simulator::new(&design.netlist);
            let mut gold = BehavioralNeuron::new(kind, &cfg);
            let mut gen = VolleyGen::new(n, 0.12, n as u64 * 31 + kind as u64);
            for _ in 0..15 {
                let volley = gen.next_volley();
                let hw = sim.step(&design.pack_inputs(&vec![false; n], 6, true))[0];
                let bm = gold.step(&vec![false; n], 6, true);
                assert_eq!(hw, bm);
                for t in 0..GAMMA_LEN {
                    let pulses = volley.pulse_bits(t);
                    let hw = sim.step(&design.pack_inputs(&pulses, 6, false))[0];
                    let bm = gold.step(&pulses, 6, false);
                    assert_eq!(hw, bm, "{kind:?} n={n} t={t}");
                }
            }
        }
    }
}

/// The Catwalk functional equivalence: under <= k simultaneous pulses the
/// TopkPc neuron output is bit-identical to the full-PC neuron output.
#[test]
fn catwalk_equals_full_pc_when_not_clipping() {
    let n = 32;
    let cfg = NeuronConfig {
        n_inputs: n,
        k: 2,
        ..Default::default()
    };
    let pc = NeuronDesign::build(DendriteKind::PcCompact, &cfg).unwrap();
    let tk = NeuronDesign::build(DendriteKind::TopkPc, &cfg).unwrap();
    let mut sim_pc = Simulator::new(&pc.netlist);
    let mut sim_tk = Simulator::new(&tk.netlist);
    let mut rng = Xoshiro256::new(77);
    for _ in 0..50 {
        sim_pc.step(&pc.pack_inputs(&vec![false; n], 5, true));
        sim_tk.step(&tk.pack_inputs(&vec![false; n], 5, true));
        // two non-overlapping-in-count pulses
        let lanes = rng.sample_indices(n, 2);
        let s0 = rng.gen_range(8);
        let s1 = rng.gen_range(8);
        let w0 = 1 + rng.gen_range(7);
        let w1 = 1 + rng.gen_range(7);
        for t in 0..GAMMA_LEN {
            let mut pulses = vec![false; n];
            pulses[lanes[0]] = t >= s0 && t < s0 + w0;
            pulses[lanes[1]] = t >= s1 && t < s1 + w1;
            let a = sim_pc.step(&pc.pack_inputs(&pulses, 5, false))[0];
            let b = sim_tk.step(&tk.pack_inputs(&pulses, 5, false))[0];
            assert_eq!(a, b);
        }
    }
}

/// Gate-level selector networks match the pure comparator model under the
/// bit-parallel simulator too (64 stimuli at once).
#[test]
fn selector_netlist_matches_model_in_simulator64() {
    let sel = TopkSelector::catwalk(16, 2).unwrap();
    let nl = sel.to_netlist("sel").unwrap();
    let mut sim = Simulator64::new(&nl);
    let mut rng = Xoshiro256::new(3);
    for _ in 0..64 {
        // build 64 lanes of random inputs
        let lane_bits: Vec<Vec<bool>> = (0..64)
            .map(|_| (0..16).map(|_| rng.gen_bool(0.2)).collect())
            .collect();
        let words: Vec<u64> = (0..16)
            .map(|i| {
                let mut w = 0u64;
                for (l, bits) in lane_bits.iter().enumerate() {
                    if bits[i] {
                        w |= 1 << l;
                    }
                }
                w
            })
            .collect();
        let out = sim.step(&words);
        for (l, bits) in lane_bits.iter().enumerate() {
            let expect = sel.apply_bits(bits);
            for (j, &e) in expect.iter().enumerate() {
                assert_eq!((out[j] >> l) & 1 == 1, e, "lane {l} tap {j}");
            }
        }
    }
}

/// Power ordering invariant at any sparsity: catwalk total <= compact
/// total for all paper sizes (the headline claim).
#[test]
fn power_ordering_invariant_across_sparsities() {
    let est = Estimator::pnr();
    for sparsity in [0.02, 0.10, 0.30] {
        let stim = StimulusConfig {
            sparsity,
            windows: 24,
            ..Default::default()
        };
        for n in [16usize, 64] {
            let cfg = NeuronConfig {
                n_inputs: n,
                k: 2,
                ..Default::default()
            };
            let pc = NeuronDesign::build(DendriteKind::PcCompact, &cfg).unwrap();
            let tk = NeuronDesign::build(DendriteKind::TopkPc, &cfg).unwrap();
            let rp = est.evaluate(&pc.netlist, Some(&measure_neuron(&pc, &stim)));
            let rt = est.evaluate(&tk.netlist, Some(&measure_neuron(&tk, &stim)));
            assert!(
                rt.total_uw() < rp.total_uw(),
                "sparsity {sparsity} n={n}: catwalk {} !< compact {}",
                rt.total_uw(),
                rp.total_uw()
            );
        }
    }
}

/// Selection works pruned from *any* verified sorter, not just the
/// tournament (Algorithm 1 is source-agnostic).
#[test]
fn pruning_any_source_gives_valid_selector() {
    for kind in SorterKind::ALL {
        for n in [8usize, 16, 32] {
            let sorter = CsNetwork::sorter(kind, n).unwrap();
            for k in [1usize, 2, 4] {
                let sel = TopkSelector::prune(&sorter, k).unwrap();
                sel.verify(12).unwrap();
            }
        }
    }
}

/// Paper Fig. 6a claim: effective gate count of the selector grows
/// monotonically with k and meets full sorting at k = n.
#[test]
fn selector_cost_meets_sorting_at_k_equals_n() {
    let n = 16;
    let full = TopkSelector::catwalk(n, n).unwrap();
    let sorter = CsNetwork::sorter(SorterKind::OddEven, n).unwrap();
    // tournament with k == n degenerates to the full odd-even sorter
    assert_eq!(full.stats().total, sorter.size());
}

/// The L3 serving stack runs end-to-end on the default (native) backend
/// with no artifacts on disk: online STDP learning over the clustered
/// workload keeps weights bounded, moves them, and leaves the column
/// responsive.
#[test]
fn serving_stack_end_to_end_on_default_backend() {
    use catwalk::coordinator::TnnHandle;
    use catwalk::tnn::workload::ClusteredSeries;
    use catwalk::tnn::{GrfEncoder, WorkloadConfig};

    let n = 32;
    let handle = TnnHandle::open("artifacts", n, 6.0, 12).unwrap();
    assert_eq!((handle.n, handle.c, handle.b), (32, 12, 64));

    let fields = 8;
    let mut enc = GrfEncoder::new(n / fields, fields, 0.0, 1.0);
    enc.cutoff = 0.60;
    let mut series = ClusteredSeries::new(WorkloadConfig {
        dims: n / fields,
        seed: 12,
        ..Default::default()
    });

    let w0 = handle.weights().unwrap();
    let mut fired_last = 0usize;
    for _ in 0..40 {
        let samples = series.next_batch(handle.b);
        let volleys: Vec<Vec<f32>> = samples.iter().map(|(_, s)| enc.encode(s)).collect();
        let results = handle.learn(volleys).unwrap();
        fired_last = results.iter().filter(|r| r.winner.is_some()).count();
    }
    let w1 = handle.weights().unwrap();
    assert_ne!(w0.data, w1.data, "STDP must move weights");
    for &w in &w1.data {
        assert!((0.0..=7.0).contains(&w), "weight {w} out of bounds");
    }
    assert!(fired_last > 0, "column must stay responsive after training");
    assert!(handle.metrics.counter("volleys_learned") >= 40 * 64);
}
