//! Multi-model registry end-to-end: framed admin surface over TCP,
//! interleaved per-model traffic, checkpoint round-trip properties,
//! restart-resume, and the bad-Load regression gate (old weights keep
//! serving).

use catwalk::proto::{Op, Outcome, Request};
use catwalk::quickprop::{forall, FnGen};
use catwalk::registry::checkpoint::{crc32, dir_has_tmp_files, Checkpoint};
use catwalk::registry::{ModelRegistry, ModelSpec, RegistryConfig};
use catwalk::rng::Xoshiro256;
use catwalk::server::{Client, FramedClient, Server};
use catwalk::{Error, SpikeVolley};
use std::path::PathBuf;
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("catwalk-registry-e2e-{tag}-{}", std::process::id()))
}

fn boot_registry(
    ckpt_dir: Option<PathBuf>,
) -> (Arc<Server>, String, std::thread::JoinHandle<()>) {
    let cfg = RegistryConfig {
        ckpt_dir,
        ..RegistryConfig::default()
    };
    let registry = Arc::new(
        ModelRegistry::open(
            cfg,
            "default",
            ModelSpec {
                n: 16,
                theta: 6.0,
                seed: 11,
            },
        )
        .unwrap(),
    );
    let server = Arc::new(Server::with_registry(registry));
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    (server, addr, srv)
}

fn stop(server: &Server, srv: std::thread::JoinHandle<()>) {
    server
        .stop_handle()
        .store(true, std::sync::atomic::Ordering::Release);
    srv.join().unwrap();
}

// ----------------------------------------------- checkpoint properties

/// save → load is bit-identical for random geometries and weights,
/// and the atomic-rename staging file never survives.
#[test]
fn prop_checkpoint_roundtrip_bit_identical() {
    let dir = temp_dir("prop");
    let _ = std::fs::remove_dir_all(&dir);
    let case = std::cell::Cell::new(0u32);
    forall(
        21,
        32,
        &FnGen(|rng: &mut Xoshiro256| {
            let n = 1 + rng.gen_range(48) as u32;
            let c = 1 + rng.gen_range(24) as u32;
            let weights: Vec<f32> = (0..(n * c) as usize)
                .map(|_| (rng.gen_f64() * 16.0 - 4.0) as f32)
                .collect();
            Checkpoint {
                n,
                c,
                t_max: 16,
                theta: (rng.gen_f64() * 20.0) as f32,
                seed: rng.next_u64(),
                weights,
            }
        }),
        |ckpt| {
            case.set(case.get() + 1);
            let path = dir.join(format!("w{}.ckpt", case.get()));
            ckpt.save(&path).unwrap();
            let back = Checkpoint::read(&path).unwrap();
            // bit-identical weights, not merely approximately equal
            let bits_match = ckpt
                .weights
                .iter()
                .zip(&back.weights)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            bits_match && back == *ckpt && !dir_has_tmp_files(&dir)
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Truncated and bit-flipped files are typed errors, never misparses.
#[test]
fn prop_corrupt_checkpoint_files_rejected() {
    let dir = temp_dir("corrupt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = Checkpoint {
        n: 8,
        c: 4,
        t_max: 16,
        theta: 6.0,
        seed: 1,
        weights: (0..32).map(|i| i as f32 / 4.0).collect(),
    };
    let path = dir.join("victim.ckpt");
    ckpt.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    let mut rng = Xoshiro256::new(22);
    for _ in 0..64 {
        let corrupted = if rng.gen_bool(0.5) {
            // truncate at a random offset
            bytes[..rng.gen_range(bytes.len())].to_vec()
        } else {
            // flip one random bit
            let mut b = bytes.clone();
            let i = rng.gen_range(b.len());
            b[i] ^= 1 << rng.gen_range(8);
            b
        };
        std::fs::write(&path, &corrupted).unwrap();
        match Checkpoint::read(&path) {
            Err(Error::Checkpoint(_)) => {}
            other => panic!("corrupt file accepted: {other:?}"),
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crc32_reference_vector() {
    // pin the polynomial: the python twin and external tools agree on
    // this IEEE vector, so the file format is tool-checkable
    assert_eq!(crc32(b"123456789"), 0xCBF43926);
}

/// Golden checkpoint bytes, shared with the python wire twin
/// (`test_checkpoint_golden_bytes` in python/tests/test_proto_frames.py):
/// n=4, c=2, t_max=16, theta=6.5, seed=0xABCD, weights
/// [1.0, 2.5, 3.0, 4.0, -0.5, 0.0, 7.0, 8.25], zlib crc32.
const GOLDEN_CKPT_HEX: &str = "43574b50000100000004000000020000001040d0000000000000\
0000abcd00000000000000083f800000402000004040000040800000bf0000000000000040e0000041040000\
f26a105c";

#[test]
fn golden_checkpoint_bytes_match_python_twin() {
    let ckpt = Checkpoint {
        n: 4,
        c: 2,
        t_max: 16,
        theta: 6.5,
        seed: 0xABCD,
        weights: vec![1.0, 2.5, 3.0, 4.0, -0.5, 0.0, 7.0, 8.25],
    };
    let bytes = ckpt.to_bytes().unwrap();
    let hex: String = bytes.iter().map(|b| format!("{b:02x}")).collect();
    assert_eq!(hex, GOLDEN_CKPT_HEX);
    assert_eq!(Checkpoint::from_bytes(&bytes).unwrap(), ckpt);
}

// ------------------------------------------------------- TCP end-to-end

/// The acceptance scenario: one framed client creates two models of
/// different (n, θ), interleaves infer/learn across both by name,
/// lists them, saves/loads checkpoints, and unloads — all over TCP.
#[test]
fn two_models_interleaved_over_tcp() {
    let dir = temp_dir("two-models");
    let _ = std::fs::remove_dir_all(&dir);
    let (server, addr, srv) = boot_registry(Some(dir.clone()));
    let mut client = FramedClient::connect(&addr).unwrap();
    assert_eq!((client.n, client.c), (16, 8), "ACK carries the default");

    // create a second, wider model with a different threshold
    let info = client.create_model("wide", 64, 12.0, 9).unwrap();
    assert_eq!((info.n, info.c), (64, 16));
    assert_eq!(info.theta, 12.0);
    assert!(!info.default);

    // duplicate create is a typed server-side error
    let err = client.create_model("wide", 16, 6.0, 1).unwrap_err();
    assert!(err.to_string().contains("already exists"), "{err}");

    // interleave infer/learn across both models by name; width follows
    // the routed model, not the connection's default
    let narrow = vec![0.0f32; 16];
    let wide = vec![0.0f32; 64];
    for _ in 0..3 {
        let (w, t) = client.infer(&narrow).unwrap();
        assert_eq!(t.len(), 8);
        assert!(w >= -1);
        let (_, t) = client.infer_model("wide", &wide).unwrap();
        assert_eq!(t.len(), 16);
        client.learn_model("wide", &wide).unwrap();
        client.learn_model("default", &narrow).unwrap();
    }
    // sending the wrong width to a routed model errors in kind
    let err = client.infer_model("wide", &narrow).unwrap_err();
    assert!(err.to_string().contains("width"), "{err}");
    // unknown model: typed proto error, not a fallback to default
    let err = client.infer_model("nope", &narrow).unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");

    // listing reflects both slots, sorted, default flagged
    let models = client.models().unwrap();
    let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
    assert_eq!(names, vec!["default", "wide"]);
    assert!(models[0].default && !models[1].default);

    // save / load round-trip over the wire
    let receipt = client.save_model("wide").unwrap();
    assert!(receipt.contains("wide.ckpt"), "{receipt}");
    let replies_after_save: Vec<(i64, Vec<f32>)> = (0..4)
        .map(|_| client.infer_model("wide", &wide).unwrap())
        .collect();
    // drift the weights, then restore them
    client.learn_model("wide", &wide).unwrap();
    client.load_model("wide").unwrap();
    for (w, t) in &replies_after_save {
        let (w2, t2) = client.infer_model("wide", &wide).unwrap();
        assert_eq!((w2, &t2), (*w, t), "restored weights serve identically");
    }

    // per-model stats carry the routed traffic; the merged snapshot
    // namespaces both models
    let ws = client.stats_model("wide").unwrap();
    assert!(ws.counter("volleys_learned") >= 4);
    let all = client.stats().unwrap();
    assert_eq!(all.counter("model.wide.n"), 64);
    assert_eq!(all.counter("model.default.default"), 1);
    assert!(all.counter("requests") >= all.counter("model.wide.requests"));

    // text clients route with the @model prefix on the same port
    let mut text = Client::connect(&addr).unwrap();
    let resp = text
        .call(&Request::infer(vec![SpikeVolley::dense(wide.clone())]).with_model("wide"))
        .unwrap();
    assert_eq!(resp.results().unwrap()[0].times.len(), 16);
    let resp = text
        .call(&Request::op(Op::Stats).with_model("wide"))
        .unwrap();
    match resp.outcome {
        Outcome::Stats(s) => assert!(s.counter("requests") >= 1),
        other => panic!("{other:?}"),
    }
    text.quit().unwrap();

    // unload the extra model; the default cannot be unloaded
    client.unload_model("wide").unwrap();
    let err = client.infer_model("wide", &wide).unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");
    let err = client.unload_model("default").unwrap_err();
    assert!(err.to_string().contains("default"), "{err}");

    client.quit().unwrap();
    stop(&server, srv);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Restart-resume: learn on a model, save, stop the server, boot a
/// fresh one over the same checkpoint directory — the reopened model
/// serves byte-identical infer replies to the pre-restart ones.
#[test]
fn save_restart_resume_identical_replies() {
    let dir = temp_dir("restart");
    let _ = std::fs::remove_dir_all(&dir);
    let volleys: Vec<Vec<f32>> = {
        let mut rng = Xoshiro256::new(77);
        (0..12)
            .map(|_| {
                (0..16)
                    .map(|_| {
                        if rng.gen_bool(0.4) {
                            rng.gen_range(8) as f32
                        } else {
                            16.0
                        }
                    })
                    .collect()
            })
            .collect()
    };

    // session 1: learn, save, record replies
    let replies: Vec<(i64, Vec<f32>)> = {
        let (server, addr, srv) = boot_registry(Some(dir.clone()));
        let mut client = FramedClient::connect(&addr).unwrap();
        for v in &volleys {
            client.learn(v).unwrap();
        }
        client.save_model("default").unwrap();
        let replies = volleys.iter().map(|v| client.infer(v).unwrap()).collect();
        client.quit().unwrap();
        stop(&server, srv);
        replies
    };

    // session 2: a brand-new server process state over the same dir
    // (load-on-open) serves the same weights
    let (server, addr, srv) = boot_registry(Some(dir.clone()));
    let mut client = FramedClient::connect(&addr).unwrap();
    for (v, (w, t)) in volleys.iter().zip(&replies) {
        let (w2, t2) = client.infer(v).unwrap();
        assert_eq!(w2, *w, "winner diverges after restart for {v:?}");
        let bits: Vec<u32> = t.iter().map(|x| x.to_bits()).collect();
        let bits2: Vec<u32> = t2.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, bits2, "times diverge after restart for {v:?}");
    }
    client.quit().unwrap();
    stop(&server, srv);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Regression gate for unload-under-load: unloading a model with
/// queued learn requests must **drain** them — every in-flight request
/// gets a real reply (computed result or typed "shut down" error),
/// no submitter hangs, and submissions through a still-held slot `Arc`
/// after the unload fail typed instead of vanishing. Runs against a
/// single-engine victim and a column-sharded one: sharded learns
/// bypass the per-shard batchers, so the typed-error guarantee needs
/// the shard layer's own stop flag, not just batcher shutdown.
#[test]
fn unload_under_load_drains_or_errors_typed() {
    for shards in [1usize, 4] {
        unload_under_load_case(shards);
    }
}

fn unload_under_load_case(shards: usize) {
    let reg = Arc::new(
        ModelRegistry::open(
            RegistryConfig::default(),
            "default",
            ModelSpec {
                n: 16,
                theta: 6.0,
                seed: 3,
            },
        )
        .unwrap(),
    );
    reg.create_sharded(
        "victim",
        ModelSpec {
            n: 16,
            theta: 6.0,
            seed: 4,
        },
        shards,
    )
    .unwrap();

    let barrier = Arc::new(std::sync::Barrier::new(5));
    let workers: Vec<_> = (0..4)
        .map(|wi| {
            let reg = reg.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                // hold the slot Arc across the unload, like a live
                // connection thread would
                let slot = reg.slot(Some("victim")).unwrap();
                barrier.wait();
                let mut answered = 0usize;
                let mut rejected = 0usize;
                for i in 0..40 {
                    let v = vec![(i % 8) as f32; 16];
                    match slot.run_batched(true, vec![SpikeVolley::dense(v)], None) {
                        Outcome::Results(rs) => {
                            assert_eq!(rs.len(), 1, "worker {wi}");
                            answered += 1;
                        }
                        Outcome::Error(e) => {
                            assert!(
                                e.contains("shut down"),
                                "worker {wi} got a non-typed failure: {e}"
                            );
                            rejected += 1;
                        }
                        other => panic!("worker {wi}: {other:?}"),
                    }
                }
                (answered, rejected)
            })
        })
        .collect();

    barrier.wait();
    // let some learns land, then unload mid-stream; unload must drain
    // (flush queued work) rather than strand blocked submitters
    std::thread::sleep(std::time::Duration::from_millis(5));
    reg.unload("victim").unwrap();
    assert!(reg.slot(Some("victim")).is_err(), "routing is gone");

    let mut total_answered = 0;
    let mut total_rejected = 0;
    for w in workers {
        // join() returning at all is the no-hang half of the gate
        let (answered, rejected) = w.join().unwrap();
        assert_eq!(answered + rejected, 40, "every request got a reply");
        total_answered += answered;
        total_rejected += rejected;
    }
    assert_eq!(total_answered + total_rejected, 160);
    // the unload raced real traffic: typically both outcomes occur,
    // but the invariant is completeness, not the split
    assert!(reg.unload("victim").is_err(), "second unload is typed");
}

/// Regression gate for the set_weights satellite: a Load whose
/// checkpoint mismatches the model's shape comes back as a typed error
/// **through the wire**, and the old weights keep serving.
#[test]
fn bad_load_is_typed_and_old_weights_keep_serving() {
    let dir = temp_dir("bad-load");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // plant a wrong-geometry checkpoint under the default model's name
    Checkpoint {
        n: 8,
        c: 4,
        t_max: 16,
        theta: 6.0,
        seed: 11,
        weights: vec![1.0; 32],
    }
    .save(&dir.join("default.ckpt"))
    .unwrap();

    // a registry opening over it refuses to come up half-loaded
    let cfg = RegistryConfig {
        ckpt_dir: Some(dir.clone()),
        ..RegistryConfig::default()
    };
    let err = ModelRegistry::open(
        cfg,
        "default",
        ModelSpec {
            n: 16,
            theta: 6.0,
            seed: 11,
        },
    )
    .unwrap_err();
    assert!(
        matches!(err, Error::Checkpoint(_)),
        "load-on-open surfaced {err:?}"
    );

    // live server: Load of the bad checkpoint errors over the wire and
    // leaves the serving weights untouched
    std::fs::remove_file(dir.join("default.ckpt")).unwrap();
    let (server, addr, srv) = boot_registry(Some(dir.clone()));
    let mut client = FramedClient::connect(&addr).unwrap();
    let volley = vec![0.0f32; 16];
    let before = client.infer(&volley).unwrap();

    Checkpoint {
        n: 8,
        c: 4,
        t_max: 16,
        theta: 6.0,
        seed: 11,
        weights: vec![1.0; 32],
    }
    .save(&dir.join("default.ckpt"))
    .unwrap();
    let err = client.load_model("default").unwrap_err();
    assert!(err.to_string().contains("wants"), "typed shape error: {err}");
    assert_eq!(
        client.infer(&volley).unwrap(),
        before,
        "old weights still serving after the failed load"
    );

    // a corrupt (bit-flipped) checkpoint is equally refused
    let mut bytes = std::fs::read(dir.join("default.ckpt")).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(dir.join("default.ckpt"), &bytes).unwrap();
    let err = client.load_model("default").unwrap_err();
    assert!(err.to_string().contains("crc"), "{err}");
    assert_eq!(client.infer(&volley).unwrap(), before);

    client.quit().unwrap();
    stop(&server, srv);
    let _ = std::fs::remove_dir_all(&dir);
}
