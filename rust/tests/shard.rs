//! Sharded-model execution end-to-end: the bit-identity contract
//! (sharded == unsharded, forward and learn), the `CWKS` shard-manifest
//! golden bytes shared with the python wire twin, and the acceptance
//! gate — byte-identical wire replies from a sharded and an unsharded
//! model over TCP on both codecs, across infer + learn +
//! save/restart/resume.

use catwalk::coordinator::{BatcherConfig, TnnHandle};
use catwalk::proto::frame;
use catwalk::quickprop::{forall, FnGen};
use catwalk::registry::{ModelRegistry, ModelSpec, RegistryConfig};
use catwalk::rng::Xoshiro256;
use catwalk::runtime::plan::{ForwardArgs, KernelPath, KernelPlan};
use catwalk::runtime::{BackendKind, Tensor};
use catwalk::server::{FramedClient, Server};
use catwalk::registry::checkpoint::{crc32, Checkpoint};
use catwalk::shard::manifest::{shard_path, ShardEntry, ShardManifest};
use catwalk::shard::{merge_result, ShardedModel};
use catwalk::SpikeVolley;
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::Arc;

fn native_env() -> bool {
    matches!(BackendKind::from_env(), Ok(BackendKind::Native))
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("catwalk-shard-e2e-{tag}-{}", std::process::id()))
}

fn random_volleys(rng: &mut Xoshiro256, rows: usize, n: usize, density: f64) -> Vec<Vec<f32>> {
    (0..rows)
        .map(|_| {
            (0..n)
                .map(|_| {
                    if rng.gen_bool(density) {
                        (rng.gen_f64() * 8.0) as f32
                    } else {
                        16.0
                    }
                })
                .collect()
        })
        .collect()
}

// --------------------------------------------------- golden CWKS bytes

/// Golden shard-manifest bytes, shared with the python wire twin
/// (`test_shard_manifest_golden_bytes` in
/// python/tests/test_proto_frames.py): n=16, c=8, t_max=16, theta=6.0,
/// seed=11, shards (0..3, 3..6, 6..8) with file CRCs 0x11111111,
/// 0x22222222, 0x33333333; zlib crc32 trailer.
const GOLDEN_CWKS_HEX: &str = "43574b53000100000010000000080000001040c00000000000000000000b\
0000000300000000000000031111111100000003000000062222222200000006000000083333333\
31f195abd";

#[test]
fn golden_shard_manifest_bytes_match_python_twin() {
    let m = ShardManifest {
        n: 16,
        c: 8,
        t_max: 16,
        theta: 6.0,
        seed: 11,
        shards: vec![
            ShardEntry { start: 0, end: 3, file_crc: 0x1111_1111 },
            ShardEntry { start: 3, end: 6, file_crc: 0x2222_2222 },
            ShardEntry { start: 6, end: 8, file_crc: 0x3333_3333 },
        ],
    };
    let bytes = m.to_bytes().unwrap();
    let hex: String = bytes.iter().map(|b| format!("{b:02x}")).collect();
    assert_eq!(hex, GOLDEN_CWKS_HEX);
    assert_eq!(ShardManifest::from_bytes(&bytes).unwrap(), m);
}

// ------------------------------------------- forward bit-identity prop

/// Sharded forward == unsharded forward bit-identically across random
/// (n, K, sparsity) — every case also pins K=1, K=c and a K that does
/// not divide c, so the remainder-distribution path is always covered.
#[test]
fn prop_sharded_forward_matches_unsharded_bitwise() {
    if !native_env() {
        return;
    }
    forall(
        47,
        10,
        &FnGen(|rng: &mut Xoshiro256| {
            let n = [16usize, 32, 64][rng.gen_range(3)];
            let density = [0.0, 0.05, 0.15, 0.5, 1.0][rng.gen_range(5)];
            let seed = rng.next_u64();
            (n, density, seed)
        }),
        |&(n, density, seed)| {
            let theta = 6.0f32;
            let solo = TnnHandle::open("/no-such-dir", n, theta, seed).unwrap();
            let c = solo.c;
            let mut rng = Xoshiro256::new(seed ^ 0x5EED);
            let volleys = random_volleys(&mut rng, 12, n, density);
            let expect = solo.infer(volleys.clone()).unwrap();
            // K=1, K=c, a K not dividing c, and a random K
            let mut ks = vec![1, c, 3, 1 + rng.gen_range(c)];
            ks.retain(|&k| k <= c);
            for k in ks {
                let sharded = ShardedModel::open(
                    "/no-such-dir",
                    n,
                    theta,
                    seed,
                    k,
                    BatcherConfig::default(),
                )
                .unwrap();
                let got: Vec<_> = sharded
                    .infer(
                        volleys.iter().cloned().map(SpikeVolley::dense).collect(),
                        None,
                    )
                    .into_iter()
                    .map(|r| r.unwrap())
                    .collect();
                for (e, g) in expect.iter().zip(&got) {
                    if e.winner != g.winner {
                        return false;
                    }
                    let eb: Vec<u32> = e.times.iter().map(|t| t.to_bits()).collect();
                    let gb: Vec<u32> = g.times.iter().map(|t| t.to_bits()).collect();
                    if eb != gb {
                        return false;
                    }
                }
                // sparse volleys travel the same path bit-identically
                let sparse: Vec<SpikeVolley> = volleys
                    .iter()
                    .map(|v| SpikeVolley::dense(v.clone()).to_sparse(sharded.t_max))
                    .collect();
                let got_sparse = sharded.infer(sparse, None);
                for (e, g) in expect.iter().zip(got_sparse) {
                    let g = g.unwrap();
                    if e.winner != g.winner || e.times != g.times {
                        return false;
                    }
                }
            }
            true
        },
    );
}

// --------------------------------------------- learn bit-identity test

/// A sequence of sharded learning steps produces bit-identical weights
/// *and* replies to the unsharded engine — the two-phase global-gate
/// protocol is exact, not approximate. Exercises winners landing in
/// different shards, globally silent rows (the search term), and
/// several shard counts including one that does not divide c.
#[test]
fn sharded_learn_matches_unsharded_bitwise() {
    if !native_env() {
        return;
    }
    let (n, theta, seed) = (16usize, 5.0f32, 77u64);
    for k in [1usize, 2, 3, 5, 8] {
        let solo = TnnHandle::open("/no-such-dir", n, theta, seed).unwrap();
        let sharded =
            ShardedModel::open("/no-such-dir", n, theta, seed, k, BatcherConfig::default())
                .unwrap();
        assert_eq!(sharded.c, solo.c);
        // identical starting weights (sliced init == full init)
        assert_eq!(
            sharded.weights().unwrap().data,
            solo.weights().unwrap().data,
            "init weights diverge at k={k}"
        );
        let mut rng = Xoshiro256::new(123);
        for step in 0..8 {
            // vary density per step so some batches have silent rows,
            // some have winners scattered across every shard
            let density = [0.0, 0.1, 0.3, 0.6][step % 4];
            let volleys = random_volleys(&mut rng, 12, n, density);
            let expect = solo.learn(volleys.clone()).unwrap();
            let got = sharded.learn(
                volleys.iter().cloned().map(SpikeVolley::dense).collect(),
                None,
            );
            for (i, (e, g)) in expect.iter().zip(got).enumerate() {
                let g = g.unwrap();
                assert_eq!(e.winner, g.winner, "k={k} step={step} volley={i}");
                let eb: Vec<u32> = e.times.iter().map(|t| t.to_bits()).collect();
                let gb: Vec<u32> = g.times.iter().map(|t| t.to_bits()).collect();
                assert_eq!(eb, gb, "k={k} step={step} volley={i}");
            }
            let wb: Vec<u32> = solo
                .weights()
                .unwrap()
                .data
                .iter()
                .map(|w| w.to_bits())
                .collect();
            let sb: Vec<u32> = sharded
                .weights()
                .unwrap()
                .data
                .iter()
                .map(|w| w.to_bits())
                .collect();
            assert_eq!(wb, sb, "weights diverge at k={k} step={step}");
        }
    }
}

#[test]
fn merge_result_is_reexported_for_gather_consumers() {
    let r = merge_result(&[4.0, 2.0, 16.0], 16);
    assert_eq!(r.winner, Some(1));
}

/// Gather regression for the PR 6 kernel dispatch redesign: the sharded
/// scatter/gather pipeline (per-shard engines → concatenation →
/// [`merge_result`]) returns exactly what every explicit [`KernelPlan`]
/// path computes on the full, unsharded weight matrix. If the new
/// dispatch layer changed the gather contract in any way — ordering,
/// tie-breaks, silent handling, path-dependent times — this diverges.
#[test]
fn sharded_gather_unchanged_under_kernel_plan_dispatch() {
    if !native_env() {
        return;
    }
    let (n, theta, seed, k) = (16usize, 6.0f32, 31u64, 3usize);
    let sharded =
        ShardedModel::open("/no-such-dir", n, theta, seed, k, BatcherConfig::default()).unwrap();
    let (c, t_max) = (sharded.c, sharded.t_max);
    let full_w = sharded.weights().unwrap();
    let mut rng = Xoshiro256::new(4242);
    for density in [0.0, 0.05, 0.25, 0.6, 1.0] {
        let volleys = random_volleys(&mut rng, 10, n, density);
        let got: Vec<_> = sharded
            .infer(
                volleys.iter().cloned().map(SpikeVolley::dense).collect(),
                None,
            )
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        let spikes = Tensor::new(
            vec![volleys.len(), n],
            volleys.iter().flatten().copied().collect(),
        )
        .unwrap();
        for path in [KernelPath::Scalar, KernelPath::Simd, KernelPath::Compacted] {
            // k_clip = 2.0: the clip the built-in manifest bakes into
            // the native forward kernel (k = 2)
            let args = ForwardArgs::new(&spikes, &full_w, theta, t_max).k_clip(Some(2.0));
            let times = KernelPlan::with_path(path).forward(&args);
            for (bi, g) in got.iter().enumerate() {
                let row: Vec<f32> = (0..c).map(|ci| times.at2(bi, ci)).collect();
                let expect = merge_result(&row, t_max);
                assert_eq!(expect.winner, g.winner, "{path:?} density {density} row {bi}");
                let eb: Vec<u32> = expect.times.iter().map(|t| t.to_bits()).collect();
                let gb: Vec<u32> = g.times.iter().map(|t| t.to_bits()).collect();
                assert_eq!(eb, gb, "{path:?} density {density} row {bi}");
            }
        }
    }
}

// ------------------------------------------------- TCP e2e (acceptance)

fn boot(
    ckpt_dir: PathBuf,
) -> (Arc<Server>, String, std::thread::JoinHandle<()>, Arc<ModelRegistry>) {
    let cfg = RegistryConfig {
        ckpt_dir: Some(ckpt_dir),
        ..RegistryConfig::default()
    };
    let spec = ModelSpec {
        n: 16,
        theta: 6.0,
        seed: 11,
    };
    let registry = Arc::new(ModelRegistry::open(cfg, "solo", spec).unwrap());
    registry.create_sharded("quad", spec, 4).unwrap();
    let server = Arc::new(Server::with_registry(registry.clone()));
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |port| {
                    let _ = port_tx.send(port);
                })
                .unwrap();
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    (server, addr, srv, registry)
}

fn stop(server: &Server, srv: std::thread::JoinHandle<()>) {
    server
        .stop_handle()
        .store(true, std::sync::atomic::Ordering::Release);
    srv.join().unwrap();
}

/// Raw text-codec round-trip: one request line in, one reply line out —
/// byte-level, so the comparison below really is wire bytes.
fn text_roundtrip(addr: &str, lines: &[String]) -> Vec<String> {
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut replies = Vec::with_capacity(lines.len());
    for line in lines {
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        replies.push(reply);
    }
    writeln!(writer, "QUIT").unwrap();
    writer.flush().unwrap();
    replies
}

/// The acceptance gate: a 4-way-sharded model and its unsharded twin
/// (same n, θ, seed) produce **byte-identical wire replies** for the
/// same traffic — infer and learn, dense and sparse, text and framed
/// codec — and still do after save / server restart / resume.
#[test]
fn sharded_and_unsharded_wire_replies_byte_identical() {
    if !native_env() {
        return;
    }
    let dir = temp_dir("twins");
    let _ = std::fs::remove_dir_all(&dir);
    let (server, addr, srv, _registry) = boot(dir.clone());

    let volleys: Vec<Vec<f32>> = {
        let mut rng = Xoshiro256::new(5);
        random_volleys(&mut rng, 10, 16, 0.3)
    };

    // --- framed codec: interleave learn + infer on both models; the
    // encoded response bytes (ids normalized) must match exactly
    let mut client = FramedClient::connect(&addr).unwrap();
    let builders: [fn(Vec<SpikeVolley>) -> catwalk::Request; 2] =
        [catwalk::Request::learn, catwalk::Request::infer];
    for v in &volleys {
        let sv = vec![SpikeVolley::dense(v.clone())];
        for build in builders {
            let mut solo = client.call(build(sv.clone()).with_model("solo")).unwrap();
            let mut quad = client.call(build(sv.clone()).with_model("quad")).unwrap();
            solo.id = 0;
            quad.id = 0;
            let solo_bytes = frame::encode_response(&solo).unwrap();
            let quad_bytes = frame::encode_response(&quad).unwrap();
            assert_eq!(solo_bytes, quad_bytes, "framed replies diverge for {v:?}");
        }
    }
    // multi-volley batch frames agree too — a 10-volley LEARN is one
    // batched kernel step on the solo side and one two-phase sharded
    // chunk on the quad side, then a 10-volley INFER probes the
    // post-step weights
    let batch: Vec<SpikeVolley> = volleys.iter().cloned().map(SpikeVolley::dense).collect();
    for build in builders {
        let mut solo = client
            .call(build(batch.clone()).with_model("solo"))
            .unwrap();
        let mut quad = client
            .call(build(batch.clone()).with_model("quad"))
            .unwrap();
        solo.id = 0;
        quad.id = 0;
        assert_eq!(
            frame::encode_response(&solo).unwrap(),
            frame::encode_response(&quad).unwrap(),
            "multi-volley batch frames diverge"
        );
    }

    // --- text codec: identical raw reply lines for dense INFER/LEARN
    // and sparse SPARSE/SLEARN, routed by @-prefix on one socket each
    let payload = |v: &Vec<f32>| {
        v.iter()
            .map(|t| format!("{t}"))
            .collect::<Vec<_>>()
            .join(",")
    };
    let sparse_payload = |v: &Vec<f32>| {
        SpikeVolley::dense(v.clone()).encode_sparse(16)
    };
    let make_lines = |model: &str| -> Vec<String> {
        let mut lines = Vec::new();
        for v in &volleys {
            lines.push(format!("@{model} LEARN {}", payload(v)));
            lines.push(format!("@{model} INFER {}", payload(v)));
            lines.push(format!("@{model} SPARSE {}", sparse_payload(v)));
            lines.push(format!("@{model} SLEARN {}", sparse_payload(v)));
        }
        lines
    };
    let solo_replies = text_roundtrip(&addr, &make_lines("solo"));
    let quad_replies = text_roundtrip(&addr, &make_lines("quad"));
    assert_eq!(solo_replies, quad_replies, "text replies diverge");

    // --- save both, restart the server over the same checkpoint dir,
    // and verify resumed replies are byte-identical to pre-restart
    // ones (mutation-free probe lines, so the weight state under
    // comparison is exactly the saved one)
    client.save_model("solo").unwrap();
    client.save_model("quad").unwrap();
    assert!(dir.join("quad.ckpt").exists(), "CWKS manifest");
    let shard_files = |prefix: &str| -> usize {
        std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with(prefix))
            .count()
    };
    // 4 content-addressed shard files (`quad.shard<i>.<crc>.ckpt`)
    assert_eq!(shard_files("quad.shard0."), 1);
    assert_eq!(shard_files("quad.shard3."), 1);
    assert!(dir.join("solo.ckpt").exists(), "plain CWKP");
    assert_eq!(shard_files("solo.shard"), 0);
    let probe_lines = |model: &str| -> Vec<String> {
        volleys
            .iter()
            .flat_map(|v| {
                [
                    format!("@{model} INFER {}", payload(v)),
                    format!("@{model} SPARSE {}", sparse_payload(v)),
                ]
            })
            .collect()
    };
    let pre_solo = text_roundtrip(&addr, &probe_lines("solo"));
    let pre_quad = text_roundtrip(&addr, &probe_lines("quad"));
    assert_eq!(pre_solo, pre_quad, "twins disagree before restart");
    client.quit().unwrap();
    stop(&server, srv);

    let (server, addr, srv, _registry) = boot(dir.clone());
    let post_solo = text_roundtrip(&addr, &probe_lines("solo"));
    let post_quad = text_roundtrip(&addr, &probe_lines("quad"));
    assert_eq!(pre_solo, post_solo, "solo resume diverges");
    assert_eq!(pre_quad, post_quad, "sharded resume diverges");
    stop(&server, srv);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------- checkpoint replication (follower)

/// One committed generation as `dist::replicate` pushes it: the `CWKS`
/// manifest bytes plus each slice's `(crc, CWKP bytes)`.
fn read_generation(path: &PathBuf) -> (Vec<u8>, Vec<(u32, Vec<u8>)>) {
    let mbytes = std::fs::read(path).unwrap();
    let m = ShardManifest::from_bytes(&mbytes).unwrap();
    let slices = m
        .shards
        .iter()
        .enumerate()
        .map(|(i, e)| {
            (
                e.file_crc,
                std::fs::read(shard_path(path, i, e.file_crc)).unwrap(),
            )
        })
        .collect();
    (mbytes, slices)
}

/// The follower's resumed weights for every `rep-s<i>` column slot,
/// as bit patterns.
fn follower_weight_bits(follower: &ModelRegistry, shards: usize) -> Vec<u32> {
    (0..shards)
        .flat_map(|i| {
            let bytes = follower.fetch_ckpt(&format!("rep-s{i}")).unwrap();
            Checkpoint::from_bytes(&bytes)
                .unwrap()
                .weights
                .iter()
                .map(|w| w.to_bits())
                .collect::<Vec<u32>>()
        })
        .collect()
}

/// Replication corruption, the follower side: a generation with a
/// bit-flipped or truncated slice is rejected **as a unit** — in
/// transit by `put_shard`'s CRC, on disk by `put_manifest`'s re-hash —
/// and the previously committed generation keeps serving and keeps
/// resuming standbys bit-identically. Once the generation is re-pushed
/// intact, the commit goes through and new standbys resume it.
#[test]
fn follower_rejects_corrupt_generation_and_keeps_prior_one() {
    if !native_env() {
        return;
    }
    let dir = temp_dir("replication");
    let _ = std::fs::remove_dir_all(&dir);
    let coord_dir = dir.join("coord");
    let follower_dir = dir.join("follower");
    std::fs::create_dir_all(&coord_dir).unwrap();

    // coordinator side: a 2-shard model, trained, committed — gen 1
    let (n, theta, seed) = (16usize, 6.0f32, 11u64);
    let model =
        ShardedModel::open("/no-such-dir", n, theta, seed, 2, BatcherConfig::default()).unwrap();
    let mut rng = Xoshiro256::new(9);
    let mut train = |model: &ShardedModel, steps: usize| {
        for _ in 0..steps {
            let volleys = random_volleys(&mut rng, 8, n, 0.3)
                .into_iter()
                .map(SpikeVolley::dense)
                .collect();
            for r in model.learn(volleys, None) {
                r.unwrap();
            }
        }
    };
    train(&model, 3);
    let gen_path = coord_dir.join("rep.ckpt");
    model.save_checkpoints(&gen_path).unwrap();
    let (m1, s1) = read_generation(&gen_path);
    let gen1_bits: Vec<u32> = model.weights().unwrap().data.iter().map(|w| w.to_bits()).collect();

    // follower: stage + commit gen 1, provision the column slots
    let follower = ModelRegistry::standby(RegistryConfig {
        artifacts_dir: "/no-such-dir".into(),
        ckpt_dir: Some(follower_dir.clone()),
        ..RegistryConfig::default()
    });
    std::fs::create_dir_all(&follower_dir).unwrap();
    for (i, (crc, bytes)) in s1.iter().enumerate() {
        follower.put_shard("rep", i, *crc, bytes).unwrap();
    }
    follower.put_manifest("rep", &m1).unwrap();
    let manifest = ShardManifest::from_bytes(&m1).unwrap();
    for (i, e) in manifest.shards.iter().enumerate() {
        follower
            .create_columns("rep", i, n, theta, seed, e.start as usize, e.end as usize)
            .unwrap();
    }
    assert_eq!(
        follower_weight_bits(&follower, 2),
        gen1_bits,
        "standby resumed gen 1 bit-identically"
    );

    // coordinator moves on: gen 2
    train(&model, 2);
    model.save_checkpoints(&gen_path).unwrap();
    let (m2, s2) = read_generation(&gen_path);
    assert_ne!(m1, m2, "gen 2 is a different generation");

    // corruption in transit: a bit-flipped slice fails put_shard's CRC
    let mut flipped = s2[0].1.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0xFF;
    assert!(
        matches!(
            follower.put_shard("rep", 0, s2[0].0, &flipped),
            Err(catwalk::Error::Checkpoint(_))
        ),
        "transit corruption is a typed checkpoint error"
    );
    // ... so the generation is incomplete and the commit is refused
    follower.put_shard("rep", 1, s2[1].0, &s2[1].1).unwrap();
    assert!(matches!(
        follower.put_manifest("rep", &m2),
        Err(catwalk::Error::Checkpoint(_))
    ));

    // corruption on disk: stage slice 0 intact, then flip a byte in
    // the staged file — put_manifest re-hashes and rejects the unit
    follower.put_shard("rep", 0, s2[0].0, &s2[0].1).unwrap();
    let staged = shard_path(&follower.ckpt_path("rep").unwrap(), 0, s2[0].0);
    std::fs::write(&staged, &flipped).unwrap();
    assert!(matches!(
        follower.put_manifest("rep", &m2),
        Err(catwalk::Error::Checkpoint(_))
    ));
    // truncation is rejected the same way
    std::fs::write(&staged, &s2[0].1[..s2[0].1.len() / 2]).unwrap();
    assert!(matches!(
        follower.put_manifest("rep", &m2),
        Err(catwalk::Error::Checkpoint(_))
    ));

    // the committed manifest is still gen 1: serving slots are
    // untouched and a *fresh* standby still resumes gen 1
    assert_eq!(std::fs::read(follower.ckpt_path("rep").unwrap()).unwrap(), m1);
    assert_eq!(follower_weight_bits(&follower, 2), gen1_bits);
    let fresh = ModelRegistry::standby(RegistryConfig {
        artifacts_dir: "/no-such-dir".into(),
        ckpt_dir: Some(follower_dir.clone()),
        ..RegistryConfig::default()
    });
    for (i, e) in manifest.shards.iter().enumerate() {
        fresh
            .create_columns("rep", i, n, theta, seed, e.start as usize, e.end as usize)
            .unwrap();
    }
    assert_eq!(
        follower_weight_bits(&fresh, 2),
        gen1_bits,
        "a restarted standby keeps resuming the prior generation"
    );

    // re-push gen 2 intact: the commit goes through, the CRC names
    // match the manifest, and a new standby resumes gen 2
    let gen2_bits: Vec<u32> = model.weights().unwrap().data.iter().map(|w| w.to_bits()).collect();
    for (i, (crc, bytes)) in s2.iter().enumerate() {
        follower.put_shard("rep", i, *crc, bytes).unwrap();
        assert_eq!(crc32(bytes), *crc);
    }
    follower.put_manifest("rep", &m2).unwrap();
    let fresh2 = ModelRegistry::standby(RegistryConfig {
        artifacts_dir: "/no-such-dir".into(),
        ckpt_dir: Some(follower_dir),
        ..RegistryConfig::default()
    });
    let m2_parsed = ShardManifest::from_bytes(&m2).unwrap();
    for (i, e) in m2_parsed.shards.iter().enumerate() {
        fresh2
            .create_columns("rep", i, n, theta, seed, e.start as usize, e.end as usize)
            .unwrap();
    }
    assert_eq!(follower_weight_bits(&fresh2, 2), gen2_bits);
    let _ = std::fs::remove_dir_all(&dir);
}
