"""L2 model tests: WTA, STDP, train_step dynamics, AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import stdp_ref, wta_ref
from compile.model import T_MAX, W_MAX, column_forward, stdp_update, train_step, wta


def test_wta_matches_ref_and_semantics():
    t = jnp.asarray(
        [
            [3.0, 1.0, 5.0],
            [16.0, 16.0, 16.0],  # nothing spiked
            [2.0, 2.0, 7.0],  # tie -> lowest index
        ]
    )
    m = wta(t, 16)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(wta_ref(t, 16)))
    np.testing.assert_array_equal(
        np.asarray(m),
        np.array([[0, 1, 0], [0, 0, 0], [1, 0, 0]], np.float32),
    )


def test_stdp_update_matches_ref():
    rng = np.random.default_rng(5)
    c, n, b = 6, 16, 32
    w = jnp.asarray(rng.uniform(0, W_MAX, (c, n)).astype(np.float32))
    t_in = jnp.asarray(
        np.where(rng.random((b, n)) < 0.3, rng.integers(0, 8, (b, n)), T_MAX).astype(
            np.float32
        )
    )
    t_out = jnp.asarray(
        np.where(rng.random((b, c)) < 0.5, rng.integers(0, 16, (b, c)), T_MAX).astype(
            np.float32
        )
    )
    mask = wta(t_out, T_MAX)
    got = stdp_update(w, t_in, t_out, mask)
    want = stdp_ref(w, t_in, t_out, mask, T_MAX)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_stdp_capture_increases_winner_weights():
    # single column, inputs spiking before output -> capture dominates.
    w = jnp.full((1, 4), 3.0)
    t_in = jnp.zeros((64, 4))
    t_out = jnp.full((64, 1), 5.0)
    mask = jnp.ones((64, 1))
    new_w = stdp_update(w, t_in, t_out, mask)
    assert np.all(np.asarray(new_w) > 3.0)


def test_stdp_bounds_respected():
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.uniform(0, W_MAX, (4, 8)).astype(np.float32))
    for _ in range(20):
        t_in = jnp.asarray(rng.integers(0, T_MAX + 1, (16, 8)).astype(np.float32))
        t_out = jnp.asarray(rng.integers(0, T_MAX + 1, (16, 4)).astype(np.float32))
        w = stdp_update(w, t_in, t_out, wta(t_out, T_MAX))
        arr = np.asarray(w)
        assert arr.min() >= 0.0 and arr.max() <= W_MAX


def test_train_step_learns_two_clusters():
    """Miniature end-to-end sanity: STDP + WTA separates two spike
    patterns onto different columns (the unsupervised-clustering behaviour
    TNN papers rely on)."""
    rng = np.random.default_rng(42)
    n, c, b = 16, 4, 64
    w = jnp.asarray(rng.uniform(2.0, 5.0, (c, n)).astype(np.float32))
    theta = jnp.asarray([[6.0]])

    def make_batch():
        # cluster A: early spikes on inputs 0..7; cluster B: on 8..15
        s = np.full((b, n), float(T_MAX), np.float32)
        labels = rng.integers(0, 2, b)
        for i, lab in enumerate(labels):
            lanes = np.arange(0, 8) if lab == 0 else np.arange(8, 16)
            chosen = rng.choice(lanes, 4, replace=False)
            s[i, chosen] = rng.integers(0, 3, 4)
        return jnp.asarray(s), labels

    for _ in range(60):
        s, _ = make_batch()
        w, _, _ = train_step(w, s, theta)

    s, labels = make_batch()
    _, mask = column_forward(s, w, theta)
    winners = np.asarray(mask).argmax(axis=1)
    fired = np.asarray(mask).sum(axis=1) > 0
    # purity: each label maps to a dominant column
    purity_num = 0
    for lab in (0, 1):
        sel = fired & (labels == lab)
        if sel.sum() == 0:
            continue
        counts = np.bincount(winners[sel], minlength=4)
        purity_num += counts.max()
    purity = purity_num / max(fired.sum(), 1)
    assert fired.mean() > 0.5, f"too few firings: {fired.mean()}"
    assert purity > 0.8, f"purity {purity}"


@pytest.mark.parametrize("n,c,b", [(16, 8, 64)])
def test_aot_lowering_produces_hlo_text(tmp_path, n, c, b):
    from functools import partial

    from compile.aot import f32, to_hlo_text

    fwd = jax.jit(partial(column_forward, k_clip=2))
    text = to_hlo_text(fwd.lower(f32(b, n), f32(c, n), f32(1, 1)))
    assert "HloModule" in text
    assert "f32[64,16]" in text.replace(" ", "")
    p = tmp_path / "fwd.hlo.txt"
    p.write_text(text)
    assert p.stat().st_size > 1000
