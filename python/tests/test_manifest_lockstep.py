"""Cross-language lockstep gate: the Rust native backend's built-in
manifest constants must match what the AOT pipeline generates.

The Rust side cannot regenerate artifacts in CI, so this test (which CI
always runs) parses the constants straight out of the Rust sources and
compares them to ``aot.CONFIGS``/``aot.K``/``model.T_MAX``. If you
change either side, change both — the native fallback and the PJRT
artifacts must describe identical column configurations.
"""

import os
import re

from compile import aot
from compile.model import T_MAX

REPO = os.path.join(os.path.dirname(__file__), "..", "..")


def _read(path):
    with open(os.path.join(REPO, path)) as f:
        return f.read()


def test_default_configs_match_aot():
    src = _read("rust/src/runtime/manifest.rs")
    m = re.search(
        r"DEFAULT_CONFIGS:\s*\[\(usize,\s*usize,\s*usize\);\s*(\d+)\]\s*=\s*\[(.*?)\];",
        src,
        re.S,
    )
    assert m, "DEFAULT_CONFIGS not found in rust/src/runtime/manifest.rs"
    count = int(m.group(1))
    triples = re.findall(r"\((\d+)\s*,\s*(\d+)\s*,\s*(\d+)\)", m.group(2))
    rust_configs = [{"n": int(n), "c": int(c), "b": int(b)} for n, c, b in triples]
    assert len(rust_configs) == count
    assert rust_configs == aot.CONFIGS, (
        f"rust DEFAULT_CONFIGS {rust_configs} != aot.CONFIGS {aot.CONFIGS}"
    )


def test_k_and_t_max_match():
    manifest_src = _read("rust/src/runtime/manifest.rs")
    k = re.search(r"const K:\s*usize\s*=\s*(\d+);", manifest_src)
    assert k and int(k.group(1)) == aot.K

    tnn_src = _read("rust/src/tnn/mod.rs")
    t = re.search(r"pub const T_MAX:\s*u32\s*=\s*(\d+);", tnn_src)
    assert t and int(t.group(1)) == T_MAX
