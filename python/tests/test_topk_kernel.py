"""Pallas unary top-k kernel vs pure-jnp oracle — the L1 correctness gate."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.networks import (
    catwalk_schedule,
    gate_count,
    prune,
    tournament_network,
)
from compile.kernels.ref import topk_wave_ref
from compile.kernels.unary_topk import times_to_waves, unary_topk

T = 16


def random_waves(rng, b, n, t, p):
    return (rng.random((b, n, t)) < p).astype(np.float32)


@pytest.mark.parametrize("n,k", [(4, 2), (8, 2), (8, 4), (16, 2), (32, 2), (64, 2), (16, 4)])
def test_kernel_matches_ref(n, k):
    rng = np.random.default_rng(n * 100 + k)
    for p in (0.05, 0.3, 0.8):
        waves = random_waves(rng, 64, n, T, p)
        got = unary_topk(jnp.asarray(waves), k)
        want = topk_wave_ref(jnp.asarray(waves), k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    n_exp=st.integers(2, 6),
    k_exp=st.integers(0, 2),
    p=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_hypothesis(n_exp, k_exp, p, seed):
    n = 1 << n_exp
    k = min(1 << k_exp, n)
    rng = np.random.default_rng(seed)
    waves = random_waves(rng, 64, n, 8, p)
    got = unary_topk(jnp.asarray(waves), k, block_b=64)
    want = topk_wave_ref(jnp.asarray(waves), k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batch_must_be_block_multiple():
    with pytest.raises(ValueError):
        unary_topk(jnp.zeros((17, 8, 4)), 2, block_b=16)


def test_times_to_waves_layout():
    s = jnp.asarray([[2.0, 99.0]])
    w = jnp.asarray([[3.0, 3.0]])
    waves = times_to_waves(s, w, 8)
    assert waves.shape == (1, 2, 8)
    np.testing.assert_array_equal(
        np.asarray(waves[0, 0]), np.array([0, 0, 1, 1, 1, 0, 0, 0], np.float32)
    )
    np.testing.assert_array_equal(np.asarray(waves[0, 1]), np.zeros(8, np.float32))


class TestNetworks:
    """Schedule construction mirrors the Rust topk module."""

    @pytest.mark.parametrize("n,k", [(4, 2), (8, 2), (16, 2), (16, 4), (32, 2), (64, 2)])
    def test_selection_zero_one(self, n, k):
        units = catwalk_schedule(n, k)
        rng = np.random.default_rng(7)
        for _ in range(300):
            bits = rng.random(n) < rng.choice([0.06, 0.5])
            lanes = bits.astype(np.int32).tolist()
            for u in units:
                a, b = lanes[u.top], lanes[u.bot]
                if u.kind in ("full", "min"):
                    lanes[u.top] = min(a, b)
                if u.kind in ("full", "max"):
                    lanes[u.bot] = max(a, b)
            taps = lanes[n - k:]
            assert sum(taps) == min(int(bits.sum()), k)
            assert all(taps[i] <= taps[i + 1] for i in range(k - 1))

    def test_gate_counts_match_rust(self):
        # pinned against rust `TopkSelector::catwalk` (see scratch data in
        # EXPERIMENTS.md): n=16 -> 44 gates, n=32 -> 92, n=64 -> 188.
        assert gate_count(catwalk_schedule(16, 2)) == 44
        assert gate_count(catwalk_schedule(32, 2)) == 92
        assert gate_count(catwalk_schedule(64, 2)) == 188

    def test_prune_rejects_nothing_when_k_equals_n(self):
        net = tournament_network(8, 8)
        units = prune(net, 8, 8)
        assert len(units) == len(net)

    def test_bad_params(self):
        with pytest.raises(ValueError):
            tournament_network(12, 2)
        with pytest.raises(ValueError):
            tournament_network(16, 3)
