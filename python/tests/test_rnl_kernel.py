"""Pallas RNL column kernel vs pure-jnp oracle + behavioral cross-checks."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import rnl_column_ref
from compile.kernels.rnl_column import rnl_column

T = 16


def random_problem(rng, b, c, n, silent_p=0.3):
    s = rng.integers(0, 8, size=(b, n)).astype(np.float32)
    silent = rng.random((b, n)) < silent_p
    s[silent] = float(T)  # no spike
    w = rng.integers(0, 8, size=(c, n)).astype(np.float32)
    theta = np.asarray([[float(rng.integers(1, 12))]], np.float32)
    return jnp.asarray(s), jnp.asarray(w), jnp.asarray(theta)


@pytest.mark.parametrize("n,c", [(16, 8), (32, 12), (64, 16)])
@pytest.mark.parametrize("k_clip", [None, 2])
def test_kernel_matches_ref(n, c, k_clip):
    rng = np.random.default_rng(n + (0 if k_clip is None else 1))
    s, w, theta = random_problem(rng, 64, c, n)
    got = rnl_column(s, w, theta, t_max=T, k_clip=k_clip)
    want = rnl_column_ref(s, w, theta, T, k_clip)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(
    n_exp=st.integers(2, 6),
    c=st.integers(1, 12),
    theta=st.integers(1, 31),
    k_clip=st.sampled_from([None, 1, 2, 4]),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_hypothesis(n_exp, c, theta, k_clip, seed):
    n = 1 << n_exp
    rng = np.random.default_rng(seed)
    s, w, _ = random_problem(rng, 64, c, n)
    th = jnp.asarray([[float(theta)]], jnp.float32)
    got = rnl_column(s, w, th, t_max=T, k_clip=k_clip)
    want = rnl_column_ref(s, w, th, T, k_clip)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_known_single_input_case():
    # one input spikes at t=1 with weight 3, theta=3 -> potential ramps
    # 1,2,3 over t=1..3 -> crossing at t=3 (matches the rust behavioral
    # reference rnl_first_crossing test).
    s = jnp.full((64, 1), 16.0).at[0, 0].set(1.0)
    w = jnp.asarray([[3.0]])
    theta = jnp.asarray([[3.0]])
    out = rnl_column(s, w, theta, t_max=T)
    assert float(out[0, 0]) == 3.0
    assert float(out[1, 0]) == float(T)  # silent row never fires


def test_clipping_delays_or_prevents_firing():
    # four simultaneous pulses, theta=8: unclipped fires at t=1
    # (4+4 >= 8); k=2 clip fires at t=3 (2,4,6,8).
    s = jnp.zeros((64, 4))
    w = jnp.full((1, 4), 7.0)
    theta = jnp.asarray([[8.0]])
    unclipped = rnl_column(s, w, theta, t_max=T, k_clip=None)
    clipped = rnl_column(s, w, theta, t_max=T, k_clip=2)
    assert float(unclipped[0, 0]) == 1.0
    assert float(clipped[0, 0]) == 3.0


def test_shape_validation():
    with pytest.raises(ValueError):
        rnl_column(jnp.zeros((64, 8)), jnp.zeros((4, 16)), jnp.zeros((1, 1)))
    with pytest.raises(ValueError):
        rnl_column(jnp.zeros((33, 8)), jnp.zeros((4, 8)), jnp.zeros((1, 1)), block_b=32)
