"""Sparse volley references vs the dense oracle (cross-language parity).

``rnl_column_sparse_ref`` is the Python twin of the historical
``runtime::native::rnl_forward_sparse``, and ``rnl_column_compacted_ref``
is the twin of its successor — the ``KernelPlan`` compacted
(software-Catwalk) path in ``rust/src/runtime/plan.rs``. All must be
exactly equal to the dense oracle, so the two languages share one
conformance story.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    dense_to_sparse,
    rnl_column_compacted_ref,
    rnl_column_ref,
    rnl_column_sparse_ref,
    sparse_to_dense,
)

T = 16


def random_dense(rng, b, n, density):
    s = np.full((b, n), float(T), np.float32)
    mask = rng.random((b, n)) < density
    s[mask] = rng.integers(0, 8, size=(b, n)).astype(np.float32)[mask]
    return s


@pytest.mark.parametrize("density", [0.0, 0.05, 0.1, 0.25, 0.5, 1.0])
@pytest.mark.parametrize("k_clip", [None, 2])
def test_sparse_ref_matches_dense_ref(density, k_clip):
    rng = np.random.default_rng(int(density * 100) + (0 if k_clip is None else 1))
    b, c, n = 16, 8, 32
    s = random_dense(rng, b, n, density)
    w = rng.integers(0, 8, size=(c, n)).astype(np.float32)
    theta = float(rng.integers(1, 12))
    want = rnl_column_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(theta), T, k_clip)
    got = rnl_column_sparse_ref(dense_to_sparse(s, T), n, w, theta, T, k_clip)
    np.testing.assert_array_equal(got, np.asarray(want))


@pytest.mark.parametrize("density", [0.0, 0.05, 0.1, 0.25, 0.5, 1.0])
@pytest.mark.parametrize("k_clip", [None, 2])
def test_compacted_ref_matches_dense_ref(density, k_clip):
    # the software-Catwalk twin (KernelPlan compacted path) equals the
    # dense oracle exactly, like its Rust counterpart in
    # rust/tests/runtime_roundtrip.rs
    rng = np.random.default_rng(int(density * 100) + (0 if k_clip is None else 1))
    b, c, n = 16, 8, 32
    s = random_dense(rng, b, n, density)
    w = rng.integers(0, 8, size=(c, n)).astype(np.float32)
    theta = float(rng.integers(1, 12))
    want = rnl_column_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(theta), T, k_clip)
    got = rnl_column_compacted_ref(s, w, theta, T, k_clip)
    np.testing.assert_array_equal(got, np.asarray(want))


def test_compacted_ref_treats_nan_as_silent():
    s = np.asarray([[2.0, np.nan, 20.0, 16.0]], np.float32)
    canonical = np.asarray([[2.0, 16.0, 16.0, 16.0]], np.float32)
    w = np.full((3, 4), 4.0, np.float32)
    got = rnl_column_compacted_ref(s, w, 1.0, T)
    want = rnl_column_ref(jnp.asarray(canonical), jnp.asarray(w), jnp.asarray(1.0), T)
    np.testing.assert_array_equal(got, np.asarray(want))


def test_roundtrip_lossless_on_canonical_volleys():
    rng = np.random.default_rng(7)
    s = random_dense(rng, 8, 24, 0.3)
    np.testing.assert_array_equal(sparse_to_dense(dense_to_sparse(s, T), 24, T), s)


def test_roundtrip_corners():
    silent = np.full((2, 8), float(T), np.float32)
    assert dense_to_sparse(silent, T) == [[], []]
    np.testing.assert_array_equal(sparse_to_dense([[], []], 8, T), silent)

    full = np.tile(np.arange(8, dtype=np.float32) % 8, (2, 1))
    lists = dense_to_sparse(full, T)
    assert all(len(row) == 8 for row in lists)
    np.testing.assert_array_equal(sparse_to_dense(lists, 8, T), full)


def test_non_canonical_silence_normalizes():
    # values >= t_max (and NaN) are silent; round-trip canonicalizes them
    s = np.asarray([[2.0, 20.0, np.nan, 16.0]], np.float32)
    lists = dense_to_sparse(s, T)
    assert lists == [[(0, 2.0)]]
    np.testing.assert_array_equal(
        sparse_to_dense(lists, 4, T),
        np.asarray([[2.0, 16.0, 16.0, 16.0]], np.float32),
    )


def test_sparse_to_dense_rejects_bad_lines():
    with pytest.raises(ValueError):
        sparse_to_dense([[(9, 1.0)]], 8, T)


@settings(max_examples=25, deadline=None)
@given(
    n_exp=st.integers(2, 6),
    c=st.integers(1, 8),
    theta=st.integers(1, 20),
    k_clip=st.sampled_from([None, 1, 2, 4]),
    density_pct=st.integers(0, 100),
    seed=st.integers(0, 2**31),
)
def test_sparse_ref_matches_dense_ref_hypothesis(n_exp, c, theta, k_clip, density_pct, seed):
    n = 1 << n_exp
    rng = np.random.default_rng(seed)
    s = random_dense(rng, 8, n, density_pct / 100.0)
    w = rng.integers(0, 8, size=(c, n)).astype(np.float32)
    want = rnl_column_ref(jnp.asarray(s), jnp.asarray(w), jnp.asarray(float(theta)), T, k_clip)
    got = rnl_column_sparse_ref(dense_to_sparse(s, T), n, w, float(theta), T, k_clip)
    np.testing.assert_array_equal(got, np.asarray(want))
    compacted = rnl_column_compacted_ref(s, w, float(theta), T, k_clip)
    np.testing.assert_array_equal(compacted, np.asarray(want))
