"""Wire-level twin of the framed protocol (``rust/src/proto/frame.rs``).

Crafts raw frames with ``struct`` against the documented layout
(README "Serving protocol" / DESIGN.md §2.2–2.3) and checks them three
ways:

1. **Golden vectors** — byte-identical constants asserted here *and* in
   ``rust/tests/proto_frames.rs``; they are the cross-language contract.
   If either side changes the layout, exactly one of the two suites
   breaks.
2. **Round-trips** — the twin codec decodes what it encodes.
3. **Malformed frames** — truncated header, bad magic, oversized
   length, unknown version/op/repr/cmd all raise instead of misparsing.

Layout (all integers big-endian, f32 = IEEE-754 bits big-endian;
constructs marked v3 are the model-registry additions — a v2 frame is
byte-for-byte a valid v3 frame without them):

    frame    := magic "CWK2" | type u8 | len u32 | payload[len]
    type     := 1 HELLO | 2 ACK | 3 REQUEST | 4 RESPONSE
    HELLO    := min_version u16 | max_version u16
    ACK      := version u16 | n u32 | c u32 | t_max u32
    REQUEST  := id u64 | op u8 | flags u8 | [deadline_ms u32]
                | [trace u64]                        (v3, flags bit 5)
                | [mlen u16 | model utf8]            (v3, flags bit 3)
                | [ngates u32 | ngates*f32]          (v3, flags bit 4,
                                                      LEARN only)
                | body
    body     := nvolleys u16 | volley*               (op 1..5)
              | cmd u8 | cmd_fields                  (op 6 ADMIN, v3)
    volley   := 0 | n u32 | n*f32            (dense)
              | 1 | n u32 | nnz u32 | nnz*(line u32, time f32)
    cmd      := 1 LIST | 2 CREATE | 3 SAVE | 4 LOAD | 5 UNLOAD
              | 6 CREATE_COLUMNS | 7 FETCH_CKPT | 8 PUT_CKPT
              | 9 PUT_SHARD | 10 PUT_MANIFEST       (v3, dist tier)
              | 11 FETCH_TRACE                      (v3, obs; no fields)
              | 12 FETCH_METRICS | 13 FETCH_HEALTH (v3, telemetry;
                                                    no fields)
    CREATE   := str16 name | n u32 | theta f32 | seed u64
    SAVE/LOAD/UNLOAD := str16 name
    CREATE_COLUMNS := str16 name | index u32 | n u32 | theta f32
                      | seed u64 | start u32 | end u32
    FETCH_CKPT := str16 name
    PUT_CKPT   := str16 name | blob32
    PUT_SHARD  := str16 name | index u32 | crc u32 | blob32
    PUT_MANIFEST := str16 name | blob32
    str16    := len u16 | utf8[len]
    blob32   := blen u32 | bytes[blen]
    RESPONSE := id u64 | status u8 | body
    RESULTS  := count u16 | (winner i32 | c u32 | c*f32)*
    ADMIN    := 0 | receipt utf8                     (v3, OK)
              | 1 | count u16 | model_row*           (v3, MODELS)
              | 2 | ckpt bytes (raw CWKP)            (v3, CKPT)
    BUSY     := retry_after_ms u32                   (v3, QoS shed;
                a v2 connection gets ERROR text instead)
    model_row := str16 name | n u32 | c u32 | t_max u32
                 | theta f32 | seed u64 | mflags u8 (bit 0 default)
"""

import struct

import pytest

MAGIC = b"CWK2"
VERSION = 3
MIN_VERSION = 2
MAX_PAYLOAD = 1 << 24

T_HELLO, T_ACK, T_REQUEST, T_RESPONSE = 1, 2, 3, 4
OP_INFER, OP_LEARN, OP_STATS, OP_PING, OP_QUIT, OP_ADMIN = 1, 2, 3, 4, 5, 6
FLAG_SPARSE_REPLY, FLAG_DEADLINE, FLAG_COUNTERS_ONLY, FLAG_MODEL = 1, 2, 4, 8
FLAG_GATES = 16
FLAG_TRACE = 32
ST_RESULTS, ST_STATS, ST_PONG, ST_BYE, ST_ERROR, ST_ADMIN, ST_BUSY = (
    0, 1, 2, 3, 4, 5, 6,
)
CMD_LIST, CMD_CREATE, CMD_SAVE, CMD_LOAD, CMD_UNLOAD = 1, 2, 3, 4, 5
CMD_CREATE_COLUMNS, CMD_FETCH_CKPT, CMD_PUT_CKPT = 6, 7, 8
CMD_PUT_SHARD, CMD_PUT_MANIFEST = 9, 10
CMD_FETCH_TRACE = 11
CMD_FETCH_METRICS, CMD_FETCH_HEALTH = 12, 13
ADMIN_OK, ADMIN_MODELS, ADMIN_CKPT = 0, 1, 2
MFLAG_DEFAULT = 1


# ----------------------------------------------------------- twin codec


def frame(ftype, payload):
    assert len(payload) <= MAX_PAYLOAD
    return MAGIC + struct.pack(">BI", ftype, len(payload)) + payload


def parse_frame(buf):
    """Returns ((type, payload), remaining). Raises ValueError on bad bytes."""
    if len(buf) < 9:
        raise ValueError("truncated frame header")
    if buf[:4] != MAGIC:
        raise ValueError("bad magic %r" % buf[:4])
    ftype, ln = struct.unpack(">BI", buf[4:9])
    if ftype not in (T_HELLO, T_ACK, T_REQUEST, T_RESPONSE):
        raise ValueError("unknown frame type %d" % ftype)
    if ln > MAX_PAYLOAD:
        raise ValueError("oversized frame: %d" % ln)
    if len(buf) < 9 + ln:
        raise ValueError("truncated frame payload")
    return (ftype, buf[9 : 9 + ln]), buf[9 + ln :]


def hello(min_version=VERSION, max_version=VERSION):
    return struct.pack(">HH", min_version, max_version)


def parse_ack(payload):
    if len(payload) != 14:
        raise ValueError("bad ACK length %d" % len(payload))
    version, n, c, t_max = struct.unpack(">HIII", payload)
    if not MIN_VERSION <= version <= VERSION:
        raise ValueError("unknown version %d" % version)
    return {"version": version, "n": n, "c": c, "t_max": t_max}


def dense_volley(times):
    return struct.pack(">BI", 0, len(times)) + b"".join(
        struct.pack(">f", t) for t in times
    )


def sparse_volley(n, pairs):
    out = struct.pack(">BII", 1, n, len(pairs))
    for line, t in pairs:
        out += struct.pack(">If", line, t)
    return out


def str16(s):
    raw = s.encode("utf-8")
    return struct.pack(">H", len(raw)) + raw


def request(rid, op, volleys=(), sparse_reply=False, deadline_ms=None,
            counters_only=False, model=None, gates=None, admin=None,
            trace=None):
    """``admin`` is the pre-encoded cmd body; required iff op is ADMIN.
    ``gates`` (a list of f32, LEARN only) is the dist tier's phase-2
    STDP gate vector — the coordinator's global-winner broadcast.
    ``trace`` (u64) is the obs tier's sampled trace id, propagated
    coordinator -> shard host so both processes record spans under one
    id."""
    flags = (
        (FLAG_SPARSE_REPLY if sparse_reply else 0)
        | (FLAG_DEADLINE if deadline_ms is not None else 0)
        | (FLAG_COUNTERS_ONLY if counters_only else 0)
        | (FLAG_MODEL if model is not None else 0)
        | (FLAG_GATES if gates is not None else 0)
        | (FLAG_TRACE if trace is not None else 0)
    )
    if gates is not None:
        assert op == OP_LEARN, "gates ride only on LEARN requests"
    p = struct.pack(">QBB", rid, op, flags)
    if deadline_ms is not None:
        p += struct.pack(">I", deadline_ms)
    if trace is not None:
        p += struct.pack(">Q", trace)
    if model is not None:
        p += str16(model)
    if gates is not None:
        p += struct.pack(">I", len(gates))
        p += b"".join(struct.pack(">f", g) for g in gates)
    if op == OP_ADMIN:
        assert not volleys and admin is not None
        return p + admin
    p += struct.pack(">H", len(volleys))
    return p + b"".join(volleys)


def cmd_list():
    return struct.pack(">B", CMD_LIST)


def cmd_create(name, n, theta, seed):
    return (
        struct.pack(">B", CMD_CREATE)
        + str16(name)
        + struct.pack(">IfQ", n, theta, seed)
    )


def cmd_named(cmd, name):
    assert cmd in (CMD_SAVE, CMD_LOAD, CMD_UNLOAD, CMD_FETCH_CKPT)
    return struct.pack(">B", cmd) + str16(name)


def blob32(b):
    return struct.pack(">I", len(b)) + b


def cmd_create_columns(name, index, n, theta, seed, start, end):
    return (
        struct.pack(">B", CMD_CREATE_COLUMNS)
        + str16(name)
        + struct.pack(">IIfQII", index, n, theta, seed, start, end)
    )


def cmd_put_ckpt(name, data):
    return struct.pack(">B", CMD_PUT_CKPT) + str16(name) + blob32(data)


def cmd_put_shard(name, index, crc, data):
    return (
        struct.pack(">B", CMD_PUT_SHARD)
        + str16(name)
        + struct.pack(">II", index, crc)
        + blob32(data)
    )


def cmd_put_manifest(name, data):
    return struct.pack(">B", CMD_PUT_MANIFEST) + str16(name) + blob32(data)


def cmd_fetch_trace():
    """Nullary v3 admin verb: drain-free snapshot of the trace ring,
    returned as a CWKT capture blob."""
    return struct.pack(">B", CMD_FETCH_TRACE)


def cmd_fetch_metrics():
    """Nullary v3 admin verb: the process's full Prometheus exposition,
    returned as utf8 text in an ADMIN CKPT reply."""
    return struct.pack(">B", CMD_FETCH_METRICS)


def cmd_fetch_health():
    """Nullary v3 admin verb: the health report (``state=``/``reason=``
    lines), returned as utf8 text in an ADMIN CKPT reply."""
    return struct.pack(">B", CMD_FETCH_HEALTH)


class Cur:
    def __init__(self, b):
        self.b, self.off = b, 0

    def take(self, fmt):
        size = struct.calcsize(fmt)
        if self.off + size > len(self.b):
            raise ValueError("short payload at offset %d" % self.off)
        vals = struct.unpack_from(fmt, self.b, self.off)
        self.off += size
        return vals if len(vals) > 1 else vals[0]

    def str16(self):
        ln = self.take(">H")
        if self.off + ln > len(self.b):
            raise ValueError("short string at offset %d" % self.off)
        raw = self.b[self.off : self.off + ln]
        self.off += ln
        return raw.decode("utf-8")

    def blob32(self):
        ln = self.take(">I")
        if self.off + ln > len(self.b):
            raise ValueError("short blob at offset %d" % self.off)
        raw = self.b[self.off : self.off + ln]
        self.off += ln
        return raw

    def finish(self):
        if self.off != len(self.b):
            raise ValueError("%d trailing bytes" % (len(self.b) - self.off))


def parse_model_cmd(cur):
    cmd = cur.take(">B")
    if cmd == CMD_LIST:
        return ("list",)
    if cmd == CMD_CREATE:
        name = cur.str16()
        n, theta, seed = cur.take(">IfQ")
        return ("create", name, n, theta, seed)
    if cmd in (CMD_SAVE, CMD_LOAD, CMD_UNLOAD, CMD_FETCH_CKPT):
        verb = {CMD_SAVE: "save", CMD_LOAD: "load", CMD_UNLOAD: "unload",
                CMD_FETCH_CKPT: "fetch_ckpt"}[cmd]
        return (verb, cur.str16())
    if cmd == CMD_CREATE_COLUMNS:
        name = cur.str16()
        index, n, theta, seed, start, end = cur.take(">IIfQII")
        return ("create_columns", name, index, n, theta, seed, start, end)
    if cmd == CMD_PUT_CKPT:
        return ("put_ckpt", cur.str16(), cur.blob32())
    if cmd == CMD_PUT_SHARD:
        name = cur.str16()
        index, crc = cur.take(">II")
        return ("put_shard", name, index, crc, cur.blob32())
    if cmd == CMD_PUT_MANIFEST:
        return ("put_manifest", cur.str16(), cur.blob32())
    if cmd == CMD_FETCH_TRACE:
        return ("fetch_trace",)
    if cmd == CMD_FETCH_METRICS:
        return ("fetch_metrics",)
    if cmd == CMD_FETCH_HEALTH:
        return ("fetch_health",)
    raise ValueError("unknown admin cmd %d" % cmd)


def parse_request(payload):
    cur = Cur(payload)
    rid, op, flags = cur.take(">QBB")
    if op not in (OP_INFER, OP_LEARN, OP_STATS, OP_PING, OP_QUIT, OP_ADMIN):
        raise ValueError("unknown op %d" % op)
    if flags & ~(FLAG_SPARSE_REPLY | FLAG_DEADLINE | FLAG_COUNTERS_ONLY
                 | FLAG_MODEL | FLAG_GATES | FLAG_TRACE):
        raise ValueError("unknown flags %#x" % flags)
    if flags & FLAG_GATES and op != OP_LEARN:
        raise ValueError("gates flag on op %d" % op)
    deadline = cur.take(">I") if flags & FLAG_DEADLINE else None
    trace = cur.take(">Q") if flags & FLAG_TRACE else None
    model = cur.str16() if flags & FLAG_MODEL else None
    gates = None
    if flags & FLAG_GATES:
        g = cur.take(">I")
        if g * 4 > len(cur.b) - cur.off:
            raise ValueError("gate count exceeds payload")
        gates = [cur.take(">f") for _ in range(g)]
    volleys = []
    admin = None
    if op == OP_ADMIN:
        admin = parse_model_cmd(cur)
    else:
        for _ in range(cur.take(">H")):
            repr_ = cur.take(">B")
            if repr_ == 0:
                n = cur.take(">I")
                if n * 4 > len(cur.b) - cur.off:
                    raise ValueError("dense count exceeds payload")
                volleys.append(("dense", [cur.take(">f") for _ in range(n)]))
            elif repr_ == 1:
                n, nnz = cur.take(">II")
                if nnz * 8 > len(cur.b) - cur.off:
                    raise ValueError("sparse count exceeds payload")
                pairs = [cur.take(">If") for _ in range(nnz)]
                if any(line >= n for line, _ in pairs):
                    raise ValueError("line out of range")
                if any(a[0] >= b[0] for a, b in zip(pairs, pairs[1:])):
                    raise ValueError("lines not strictly ascending")
                volleys.append(("sparse", n, pairs))
            else:
                raise ValueError("unknown volley repr %d" % repr_)
    cur.finish()
    return {
        "id": rid,
        "op": op,
        "volleys": volleys,
        "sparse_reply": bool(flags & FLAG_SPARSE_REPLY),
        "deadline_ms": deadline,
        "counters_only": bool(flags & FLAG_COUNTERS_ONLY),
        "model": model,
        "gates": gates,
        "admin": admin,
        "trace": trace,
    }


def response_results(rid, results):
    p = struct.pack(">QBH", rid, ST_RESULTS, len(results))
    for winner, times in results:
        p += struct.pack(">iI", winner, len(times))
        p += b"".join(struct.pack(">f", t) for t in times)
    return p


def response_admin_ok(rid, receipt):
    return struct.pack(">QBB", rid, ST_ADMIN, ADMIN_OK) + receipt.encode("utf-8")


def response_busy(rid, retry_after_ms):
    """QoS load shed (v3-only): admission refused, retry hint in ms."""
    return struct.pack(">QBI", rid, ST_BUSY, retry_after_ms)


def response_admin_models(rid, rows):
    """rows: (name, n, c, t_max, theta, seed, default) tuples."""
    p = struct.pack(">QBBH", rid, ST_ADMIN, ADMIN_MODELS, len(rows))
    for name, n, c, t_max, theta, seed, default in rows:
        p += str16(name)
        p += struct.pack(">IIIfQB", n, c, t_max, theta, seed,
                         MFLAG_DEFAULT if default else 0)
    return p


def response_admin_ckpt(rid, data):
    """Raw checkpoint bytes (CWKP, or CWKS for a manifest) — the file's
    own trailing CRC-32 is the integrity check, so no extra framing."""
    return struct.pack(">QBB", rid, ST_ADMIN, ADMIN_CKPT) + data


def parse_response(payload):
    cur = Cur(payload)
    rid, status = cur.take(">QB")
    if status == ST_RESULTS:
        results = []
        for _ in range(cur.take(">H")):
            winner, c = cur.take(">iI")
            if c * 4 > len(cur.b) - cur.off:
                raise ValueError("result count exceeds payload")
            results.append((winner, [cur.take(">f") for _ in range(c)]))
        cur.finish()
        return {"id": rid, "results": results}
    if status in (ST_STATS, ST_ERROR):
        body = cur.b[cur.off :].decode("utf-8")
        return {"id": rid, ("stats" if status == ST_STATS else "error"): body}
    if status in (ST_PONG, ST_BYE):
        cur.finish()
        return {"id": rid, "status": "pong" if status == ST_PONG else "bye"}
    if status == ST_ADMIN:
        kind = cur.take(">B")
        if kind == ADMIN_OK:
            return {"id": rid, "receipt": cur.b[cur.off :].decode("utf-8")}
        if kind == ADMIN_MODELS:
            rows = []
            for _ in range(cur.take(">H")):
                name = cur.str16()
                n, c, t_max, theta, seed, mflags = cur.take(">IIIfQB")
                if mflags & ~MFLAG_DEFAULT:
                    raise ValueError("unknown model row flags %#x" % mflags)
                rows.append((name, n, c, t_max, theta, seed,
                             bool(mflags & MFLAG_DEFAULT)))
            cur.finish()
            return {"id": rid, "models": rows}
        if kind == ADMIN_CKPT:
            # raw CWKP (or CWKS) bytes — self-checksummed, no framing
            return {"id": rid, "ckpt": cur.b[cur.off :]}
        raise ValueError("unknown admin reply kind %d" % kind)
    if status == ST_BUSY:
        retry = cur.take(">I")
        cur.finish()
        return {"id": rid, "busy_retry_after_ms": retry}
    raise ValueError("unknown response status %d" % status)


# ------------------------------------------------------- golden vectors

# The same constants appear in rust/tests/proto_frames.rs. Request:
# id=7, INFER, sparse_reply + deadline 250 ms, two volleys —
# dense [1.0, 16.0, 2.5, 16.0] and sparse n=4 {(1, 3.0)}.
GOLDEN_REQUEST_HEX = (
    "43574b32030000003600000000000000070103000000fa00020000000004"
    "3f8000004180000040200000418000000100000004000000010000000140400000"
)

# Response: id=7, one result, winner=2, times=[4.0, 16.0, 2.0].
GOLDEN_RESPONSE_HEX = (
    "43574b32040000001f000000000000000700000100000002000000034080"
    "00004180000040000000"
)

# HELLO [2,2] and ACK v2 for an n=16, c=8, t_max=16 column.
GOLDEN_HELLO_HEX = "43574b32010000000400020002"
GOLDEN_ACK_HEX = "43574b32020000000e0002000000100000000800000010"

# --- v3 (model registry) golden vectors, also asserted in
# --- rust/tests/proto_frames.rs.

# Request: id=7, INFER routed to model "edge" (flag bit 3 only), one
# dense volley [1.0, 16.0, 2.5, 16.0].
GOLDEN_MODEL_REQUEST_HEX = (
    "43574b32030000002700000000000000070108000465646765000100000000"
    "043f800000418000004020000041800000"
)

# Request: id=8, ADMIN CREATE { name="edge", n=16, theta=6.0, seed=5 }.
GOLDEN_ADMIN_CREATE_HEX = (
    "43574b32030000002100000000000000080600020004656467650000001040"
    "c000000000000000000005"
)

# Request: id=9, ADMIN LIST.
GOLDEN_ADMIN_LIST_HEX = "43574b32030000000b0000000000000009060001"

# Response: id=9, MODELS [default(n=64,c=16,t_max=16,theta=6,seed=7)*,
# edge(n=16,c=8,t_max=16,theta=6,seed=5)] — * = default flag.
GOLDEN_MODELS_RESPONSE_HEX = (
    "43574b32040000004d000000000000000905010002000764656661756c7400"
    "000040000000100000001040c0000000000000000000070100046564676500"
    "000010000000080000001040c00000000000000000000500"
)

# HELLO [2,3] (what a v3 client sends) and a v3 ACK for the n=64 column.
GOLDEN_HELLO_V3_HEX = "43574b32010000000400020003"
GOLDEN_ACK_V3_HEX = "43574b32020000000e0003000000400000001000000010"

# Response: id=7, BUSY with retry hint 250 ms — the QoS load-shed reply
# (status 6, v3-only; PR 7). Shared with rust/tests/proto_frames.rs
# (golden_busy_bytes_match_python_twin). On a v2 connection the server
# degrades this to ST_ERROR with the rendered message
# "server busy, retry after 250 ms"; the legacy text codec sends the
# line "BUSY 250\n".
GOLDEN_BUSY_RESPONSE_HEX = "43574b32040000000d000000000000000706000000fa"
BUSY_TEXT_LINE = b"BUSY 250\n"


def golden_request_bytes():
    return frame(
        T_REQUEST,
        request(
            7,
            OP_INFER,
            volleys=[
                dense_volley([1.0, 16.0, 2.5, 16.0]),
                sparse_volley(4, [(1, 3.0)]),
            ],
            sparse_reply=True,
            deadline_ms=250,
        ),
    )


def golden_response_bytes():
    return frame(T_RESPONSE, response_results(7, [(2, [4.0, 16.0, 2.0])]))


def golden_hello_bytes():
    return frame(T_HELLO, hello(2, 2))


def golden_ack_bytes():
    return frame(T_ACK, struct.pack(">HIII", 2, 16, 8, 16))


def golden_model_request_bytes():
    return frame(
        T_REQUEST,
        request(
            7,
            OP_INFER,
            volleys=[dense_volley([1.0, 16.0, 2.5, 16.0])],
            model="edge",
        ),
    )


def golden_admin_create_bytes():
    return frame(T_REQUEST, request(8, OP_ADMIN, admin=cmd_create("edge", 16, 6.0, 5)))


def golden_admin_list_bytes():
    return frame(T_REQUEST, request(9, OP_ADMIN, admin=cmd_list()))


def golden_busy_response_bytes():
    return frame(T_RESPONSE, response_busy(7, 250))


def golden_models_response_bytes():
    return frame(
        T_RESPONSE,
        response_admin_models(
            9,
            [
                ("default", 64, 16, 16, 6.0, 7, True),
                ("edge", 16, 8, 16, 6.0, 5, False),
            ],
        ),
    )


# ----------------------------------------------------------------- tests


def test_golden_request_bytes_match_contract():
    assert golden_request_bytes().hex() == GOLDEN_REQUEST_HEX


def test_golden_response_bytes_match_contract():
    assert golden_response_bytes().hex() == GOLDEN_RESPONSE_HEX


def test_golden_handshake_bytes_match_contract():
    assert golden_hello_bytes().hex() == GOLDEN_HELLO_HEX
    assert golden_ack_bytes().hex() == GOLDEN_ACK_HEX


def test_request_roundtrip():
    (ftype, payload), rest = parse_frame(golden_request_bytes())
    assert (ftype, rest) == (T_REQUEST, b"")
    req = parse_request(payload)
    assert req["id"] == 7
    assert req["op"] == OP_INFER
    assert req["sparse_reply"] and req["deadline_ms"] == 250
    assert not req["counters_only"]
    assert req["volleys"][0] == ("dense", [1.0, 16.0, 2.5, 16.0])
    assert req["volleys"][1] == ("sparse", 4, [(1, 3.0)])


def test_response_roundtrip_and_statuses():
    (_, payload), _ = parse_frame(golden_response_bytes())
    resp = parse_response(payload)
    assert resp == {"id": 7, "results": [(2, [4.0, 16.0, 2.0])]}

    # winner -1 = silent; two's-complement i32 on the wire
    p = response_results(9, [(-1, [16.0])])
    assert parse_response(p)["results"] == [(-1, [16.0])]

    stats = struct.pack(">QB", 3, ST_STATS) + b"counter.requests=5\nschema=1\n"
    assert parse_response(stats)["stats"] == "counter.requests=5\nschema=1\n"
    err = struct.pack(">QB", 3, ST_ERROR) + "boom ✗".encode("utf-8")
    assert parse_response(err)["error"] == "boom ✗"
    assert parse_response(struct.pack(">QB", 1, ST_PONG))["status"] == "pong"
    assert parse_response(struct.pack(">QB", 1, ST_BYE))["status"] == "bye"


def test_ack_parses_geometry():
    (ftype, payload), _ = parse_frame(golden_ack_bytes())
    assert ftype == T_ACK
    assert parse_ack(payload) == {"version": 2, "n": 16, "c": 8, "t_max": 16}
    with pytest.raises(ValueError):
        parse_ack(struct.pack(">HIII", 9, 1, 1, 1))  # unknown version
    with pytest.raises(ValueError):
        parse_ack(b"\x00\x02")  # truncated


def test_frames_concatenate_for_pipelining():
    buf = golden_request_bytes() * 3
    seen = []
    while buf:
        (ftype, payload), buf = parse_frame(buf)
        seen.append(ftype)
    assert seen == [T_REQUEST] * 3


@pytest.mark.parametrize(
    "mutate",
    [
        lambda b: b[:3],  # truncated header
        lambda b: b[:11],  # truncated payload
        lambda b: b"XWK2" + b[4:],  # bad magic
        lambda b: b[:4] + struct.pack(">BI", 9, 0),  # unknown frame type
        lambda b: b[:4] + struct.pack(">BI", T_REQUEST, MAX_PAYLOAD + 1),  # oversized
    ],
)
def test_malformed_frames_raise(mutate):
    with pytest.raises(ValueError):
        parse_frame(mutate(golden_request_bytes()))


def test_malformed_request_payloads_raise():
    good = request(1, OP_INFER, [dense_volley([1.0, 2.0])])
    parse_request(good)  # sanity
    for cut in range(len(good)):
        with pytest.raises(ValueError):
            parse_request(good[:cut])
    with pytest.raises(ValueError):
        parse_request(good + b"\x00")  # trailing bytes
    with pytest.raises(ValueError):
        parse_request(request(1, 99, []))  # unknown op
    bad_flags = struct.pack(">QBB", 1, OP_PING, 0x80) + struct.pack(">H", 0)
    with pytest.raises(ValueError):
        parse_request(bad_flags)
    # hostile dense count must not be trusted
    huge = struct.pack(">QBB", 1, OP_INFER, 0) + struct.pack(">H", 1)
    huge += struct.pack(">BI", 0, 0xFFFFFFFF)
    with pytest.raises(ValueError):
        parse_request(huge)
    # sparse invariants: out-of-range line, unsorted lines
    with pytest.raises(ValueError):
        parse_request(request(1, OP_INFER, [sparse_volley(4, [(9, 1.0)])]))
    with pytest.raises(ValueError):
        parse_request(
            request(1, OP_INFER, [sparse_volley(4, [(2, 1.0), (1, 1.0)])])
        )


def test_golden_v3_vectors_match_contract():
    assert golden_model_request_bytes().hex() == GOLDEN_MODEL_REQUEST_HEX
    assert golden_admin_create_bytes().hex() == GOLDEN_ADMIN_CREATE_HEX
    assert golden_admin_list_bytes().hex() == GOLDEN_ADMIN_LIST_HEX
    assert golden_models_response_bytes().hex() == GOLDEN_MODELS_RESPONSE_HEX
    assert frame(T_HELLO, hello(2, 3)).hex() == GOLDEN_HELLO_V3_HEX
    assert (
        frame(T_ACK, struct.pack(">HIII", 3, 64, 16, 16)).hex() == GOLDEN_ACK_V3_HEX
    )
    # the v3 ACK parses under the twin's version window [2, 3]
    (ftype, payload), _ = parse_frame(frame(T_ACK, struct.pack(">HIII", 3, 64, 16, 16)))
    assert parse_ack(payload)["version"] == 3


def test_model_request_roundtrip():
    (ftype, payload), rest = parse_frame(golden_model_request_bytes())
    assert (ftype, rest) == (T_REQUEST, b"")
    req = parse_request(payload)
    assert req["model"] == "edge"
    assert req["op"] == OP_INFER and req["id"] == 7
    assert req["volleys"] == [("dense", [1.0, 16.0, 2.5, 16.0])]
    assert req["admin"] is None
    # without the flag the model field is absent — the v2 layout exactly
    bare = request(7, OP_INFER, volleys=[dense_volley([1.0])])
    assert parse_request(bare)["model"] is None
    # model composes with the other flags (deadline sits before it)
    both = request(1, OP_LEARN, volleys=[dense_volley([2.0])],
                   deadline_ms=50, model="edge", sparse_reply=True)
    req = parse_request(both)
    assert (req["deadline_ms"], req["model"]) == (50, "edge")


def test_admin_frames_roundtrip_and_reject_garbage():
    (_, payload), _ = parse_frame(golden_admin_create_bytes())
    req = parse_request(payload)
    assert req["op"] == OP_ADMIN
    assert req["admin"] == ("create", "edge", 16, 6.0, 5)
    (_, payload), _ = parse_frame(golden_admin_list_bytes())
    assert parse_request(payload)["admin"] == ("list",)
    for cmd, verb in [(CMD_SAVE, "save"), (CMD_LOAD, "load"), (CMD_UNLOAD, "unload")]:
        p = request(3, OP_ADMIN, admin=cmd_named(cmd, "edge"))
        assert parse_request(p)["admin"] == (verb, "edge")
    # unknown cmd byte, truncated name, trailing bytes: all raise
    with pytest.raises(ValueError):
        parse_request(request(3, OP_ADMIN, admin=struct.pack(">B", 99)))
    with pytest.raises(ValueError):
        parse_request(request(3, OP_ADMIN, admin=struct.pack(">B", CMD_SAVE) + str16("edge")[:3]))
    with pytest.raises(ValueError):
        parse_request(request(3, OP_ADMIN, admin=cmd_list() + b"\x00"))
    # every truncation of the create frame raises
    good = request(8, OP_ADMIN, admin=cmd_create("edge", 16, 6.0, 5))
    for cut in range(len(good)):
        with pytest.raises(ValueError):
            parse_request(good[:cut])


def test_golden_busy_response_bytes_match_contract():
    assert golden_busy_response_bytes().hex() == GOLDEN_BUSY_RESPONSE_HEX


def test_busy_response_roundtrip_and_degrade():
    (ftype, payload), rest = parse_frame(golden_busy_response_bytes())
    assert (ftype, rest) == (T_RESPONSE, b"")
    assert payload[8] == ST_BUSY
    resp = parse_response(payload)
    assert resp == {"id": 7, "busy_retry_after_ms": 250}
    # every truncation of the payload raises instead of misparsing
    for cut in range(len(payload)):
        with pytest.raises(ValueError):
            parse_response(payload[:cut])
    # ...and so do trailing bytes after the retry hint
    with pytest.raises(ValueError):
        parse_response(payload + b"\x00")
    # the v2 degrade is an ordinary ERROR frame with the rendered text —
    # a v2-only reader never sees status 6 on the wire
    degraded = struct.pack(">QB", 7, ST_ERROR) + b"server busy, retry after 250 ms"
    assert parse_response(degraded)["error"] == "server busy, retry after 250 ms"
    # legacy text codec: same shed as a parseable one-line reply
    assert BUSY_TEXT_LINE.decode("ascii") == "BUSY %d\n" % 250


def test_admin_response_roundtrip():
    ok = response_admin_ok(4, "saved edge to checkpoints/edge.ckpt")
    assert parse_response(ok)["receipt"].startswith("saved edge")
    (_, payload), _ = parse_frame(golden_models_response_bytes())
    resp = parse_response(payload)
    assert resp["id"] == 9
    assert resp["models"] == [
        ("default", 64, 16, 16, 6.0, 7, True),
        ("edge", 16, 8, 16, 6.0, 5, False),
    ]
    # unknown reply kind / model-row flags raise
    with pytest.raises(ValueError):
        parse_response(struct.pack(">QBB", 1, ST_ADMIN, 9))
    bad_row = response_admin_models(1, [("m", 1, 1, 1, 1.0, 1, False)])
    bad_row = bad_row[:-1] + b"\x80"
    with pytest.raises(ValueError):
        parse_response(bad_row)


def test_gated_learn_request_roundtrip():
    """The dist tier's phase-2 LEARN carries the coordinator's global
    gate vector (flags bit 4, v3). Gates are 0.0/1.0 floats, one per
    (row, local column) cell of the shard."""
    gates = [1.0, 0.0, 0.0, 1.0, 0.0, 1.0]
    p = request(5, OP_LEARN, volleys=[dense_volley([1.0, 16.0])],
                model="dist-s0", gates=gates)
    req = parse_request(p)
    assert req["op"] == OP_LEARN and req["model"] == "dist-s0"
    assert req["gates"] == gates
    assert req["volleys"] == [("dense", [1.0, 16.0])]
    # without the flag the field is absent — a v2 LEARN exactly
    bare = request(5, OP_LEARN, volleys=[dense_volley([1.0, 16.0])])
    assert parse_request(bare)["gates"] is None
    # every truncation raises instead of misparsing
    for cut in range(len(p)):
        with pytest.raises(ValueError):
            parse_request(p[:cut])
    # gates on a non-LEARN op is a typed error, not a silent skip:
    # craft the bytes by hand since the builder refuses to
    bad = struct.pack(">QBB", 5, OP_INFER, FLAG_GATES)
    bad += struct.pack(">I", 1) + struct.pack(">f", 1.0)
    bad += struct.pack(">H", 0)
    with pytest.raises(ValueError):
        parse_request(bad)
    # hostile gate count must not be trusted
    huge = struct.pack(">QBB", 5, OP_LEARN, FLAG_GATES)
    huge += struct.pack(">I", 0xFFFFFFFF)
    with pytest.raises(ValueError):
        parse_request(huge)


def test_dist_admin_cmds_roundtrip():
    """The v3 admin verbs the distributed shard tier adds: shard-slot
    provisioning (CREATE_COLUMNS) and checkpoint replication
    (FETCH/PUT_CKPT, PUT_SHARD, PUT_MANIFEST)."""
    p = request(3, OP_ADMIN,
                admin=cmd_create_columns("dist", 1, 16, 6.0, 11, 8, 16))
    assert parse_request(p)["admin"] == (
        "create_columns", "dist", 1, 16, 6.0, 11, 8, 16)
    p = request(3, OP_ADMIN, admin=cmd_named(CMD_FETCH_CKPT, "dist-s1"))
    assert parse_request(p)["admin"] == ("fetch_ckpt", "dist-s1")
    p = request(3, OP_ADMIN, admin=cmd_put_ckpt("dist-s1", b"\x01\x02"))
    assert parse_request(p)["admin"] == ("put_ckpt", "dist-s1", b"\x01\x02")
    p = request(3, OP_ADMIN,
                admin=cmd_put_shard("dist", 1, 0xDEADBEEF, b"\x03\x04\x05"))
    assert parse_request(p)["admin"] == (
        "put_shard", "dist", 1, 0xDEADBEEF, b"\x03\x04\x05")
    p = request(3, OP_ADMIN, admin=cmd_put_manifest("dist", b""))
    assert parse_request(p)["admin"] == ("put_manifest", "dist", b"")
    # every truncation of the widest verb raises
    good = request(3, OP_ADMIN,
                   admin=cmd_put_shard("dist", 1, 7, b"\x00" * 9))
    for cut in range(len(good)):
        with pytest.raises(ValueError):
            parse_request(good[:cut])
    # a blob length claiming past the payload end raises
    bad = request(3, OP_ADMIN, admin=cmd_put_manifest("dist", b"\x00" * 4))
    bad = bad[:-8] + struct.pack(">I", 64) + b"\x00" * 4
    with pytest.raises(ValueError):
        parse_request(bad)


def test_admin_ckpt_response_roundtrip():
    """FETCH_CKPT replies with the raw checkpoint file bytes; the CWKP
    trailer CRC is the end-to-end integrity check the follower re-runs
    before staging a replicated slice."""
    import zlib

    body = checkpoint_bytes(4, 1, 16, 6.0, 3, [0.5, 1.0, 0.0, 2.0])
    p = response_admin_ckpt(11, body)
    resp = parse_response(p)
    assert resp["id"] == 11 and resp["ckpt"] == body
    stored = struct.unpack(">I", resp["ckpt"][-4:])[0]
    assert stored == zlib.crc32(resp["ckpt"][:-4]) & 0xFFFFFFFF
    # an empty body is representable (the reply is just "the bytes")
    assert parse_response(response_admin_ckpt(11, b""))["ckpt"] == b""


# ------------------------------------------- checkpoint file twin (CWKP)

CKPT_MAGIC = b"CWKP"
CKPT_SCHEMA = 1

# Shared with rust/tests/registry.rs (golden_checkpoint_bytes_match_
# python_twin): n=4, c=2, t_max=16, theta=6.5, seed=0xABCD, weights
# [1.0, 2.5, 3.0, 4.0, -0.5, 0.0, 7.0, 8.25].
GOLDEN_CKPT_HEX = (
    "43574b50000100000004000000020000001040d00000000000000000abcd0000"
    "0000000000083f800000402000004040000040800000bf000000000000004"
    "0e0000041040000f26a105c"
)


def checkpoint_bytes(n, c, t_max, theta, seed, weights):
    """``registry/checkpoint.rs`` layout: header | f32 weights | crc32."""
    import zlib

    assert len(weights) == n * c
    p = CKPT_MAGIC + struct.pack(
        ">HIIIfQQ", CKPT_SCHEMA, n, c, t_max, theta, seed, len(weights)
    )
    p += b"".join(struct.pack(">f", w) for w in weights)
    return p + struct.pack(">I", zlib.crc32(p) & 0xFFFFFFFF)


def test_checkpoint_golden_bytes():
    b = checkpoint_bytes(
        4, 2, 16, 6.5, 0xABCD, [1.0, 2.5, 3.0, 4.0, -0.5, 0.0, 7.0, 8.25]
    )
    assert b.hex() == GOLDEN_CKPT_HEX
    # fixed header (38) + 8 weights + crc
    assert len(b) == 38 + 8 * 4 + 4
    # the trailing crc covers everything before it (zlib == IEEE 802.3,
    # the polynomial rust's registry::checkpoint::crc32 implements)
    import zlib

    stored = struct.unpack(">I", b[-4:])[0]
    assert stored == zlib.crc32(b[:-4]) & 0xFFFFFFFF
    # a bit flip anywhere breaks the crc — the property rust enforces
    flipped = bytearray(b)
    flipped[10] ^= 1
    assert struct.unpack(">I", bytes(flipped[-4:]))[0] != (
        zlib.crc32(bytes(flipped[:-4])) & 0xFFFFFFFF
    )


def test_stats_kv_schema_shape():
    """The STATS body is line-oriented key=value, sorted by key; the
    schema=2 registry rows namespace per-model metrics under
    ``model.<name>.`` and keep plain keys as the cross-model aggregate."""
    body = (
        "counter.model.edge.n=16\n"
        "counter.model.edge.requests=3\n"
        "counter.requests=5\n"
        "hist.lat.p50_us=64\n"
        "hist.model.edge.lat.p50_us=32\n"
        "schema=2\n"
    )
    lines = body.strip().splitlines()
    assert lines == sorted(lines)
    parsed = dict(line.split("=", 1) for line in lines)
    assert parsed["schema"] == "2"
    assert int(parsed["counter.requests"]) == 5
    # per-model rows are ordinary keys under the model.<name>. prefix,
    # so a schema=1 reader that skips unknown keys keeps working
    assert int(parsed["counter.model.edge.requests"]) == 3
    assert int(parsed["counter.model.edge.n"]) == 16
    assert int(parsed["hist.model.edge.lat.p50_us"]) == 32


def test_stats_kv_shard_rows():
    """A column-sharded model adds ``model.<name>.shard.<i>.*`` rows
    under the same schema=2 grammar: model-level keys count each request
    once (the scatter/gather layer's view), shard keys expose each
    engine's private counters plus its column count, and the ``shards``
    geometry row says how the model is split. Model names are
    allowlisted to [A-Za-z0-9_-], so the ``.shard.<i>.`` segment can
    never collide with a model name."""
    body = (
        "counter.model.quad.requests=5\n"
        "counter.model.quad.shard.0.c=2\n"
        "counter.model.quad.shard.0.requests=5\n"
        "counter.model.quad.shard.1.c=2\n"
        "counter.model.quad.shard.1.requests=5\n"
        "counter.model.quad.shards=2\n"
        "counter.requests=5\n"
        "hist.model.quad.shard.0.batch_exec.p50_us=16\n"
        "schema=2\n"
    )
    lines = body.strip().splitlines()
    assert lines == sorted(lines)
    parsed = dict(line.split("=", 1) for line in lines)
    assert parsed["schema"] == "2"
    k = int(parsed["counter.model.quad.shards"])
    assert k == 2
    # every shard 0..k-1 has a column count, and they tile the model
    per_shard_c = [
        int(parsed["counter.model.quad.shard.%d.c" % i]) for i in range(k)
    ]
    assert all(c >= 1 for c in per_shard_c)
    # each shard engine saw every scattered request; the model-level
    # (and plain aggregate) rows count them once, not k times
    assert int(parsed["counter.model.quad.requests"]) == 5
    assert int(parsed["counter.requests"]) == 5
    assert int(parsed["counter.model.quad.shard.1.requests"]) == 5
    # shard keys parse under the schema-1 grammar (skip-unknown-keys
    # readers keep working)
    for key in parsed:
        assert "=" not in key and " " not in key


# --------------------------------------- shard-manifest twin (CWKS)

CWKS_MAGIC = b"CWKS"
CWKS_SCHEMA = 1

# Shared with rust/tests/shard.rs (golden_shard_manifest_bytes_match_
# python_twin): n=16, c=8, t_max=16, theta=6.0, seed=11, three shards
# (0..3, 3..6, 6..8) with file CRCs 0x11111111/0x22222222/0x33333333.
GOLDEN_CWKS_HEX = (
    "43574b53000100000010000000080000001040c00000000000000000000b"
    "000000030000000000000003111111110000000300000006222222220000"
    "000600000008333333331f195abd"
)


def shard_manifest_bytes(n, c, t_max, theta, seed, shards):
    """``shard/manifest.rs`` layout: header | (start, end, crc)* | crc32.

    ``shards`` is a list of (start, end, file_crc) tuples — the CRC-32
    of each shard's complete CWKP file bytes, which is how the loader
    proves all K files belong to one save generation.
    """
    import zlib

    p = CWKS_MAGIC + struct.pack(
        ">HIIIfQI", CWKS_SCHEMA, n, c, t_max, theta, seed, len(shards)
    )
    for start, end, crc in shards:
        p += struct.pack(">III", start, end, crc)
    return p + struct.pack(">I", zlib.crc32(p) & 0xFFFFFFFF)


def test_shard_manifest_golden_bytes():
    b = shard_manifest_bytes(
        16, 8, 16, 6.0, 11,
        [(0, 3, 0x11111111), (3, 6, 0x22222222), (6, 8, 0x33333333)],
    )
    assert b.hex() == GOLDEN_CWKS_HEX
    # fixed header (34) + 3 entries (12 each) + crc
    assert len(b) == 34 + 3 * 12 + 4
    import zlib

    stored = struct.unpack(">I", b[-4:])[0]
    assert stored == zlib.crc32(b[:-4]) & 0xFFFFFFFF
    # the entry table is a contiguous ascending partition of 0..c —
    # the property rust's validate_partition enforces
    entries = [
        struct.unpack_from(">III", b, 34 + i * 12) for i in range(3)
    ]
    assert entries[0][0] == 0
    assert entries[-1][1] == 8
    for (s0, e0, _), (s1, e1, _) in zip(entries, entries[1:]):
        assert e0 == s1 and s0 < e0 < e1
    # a bit flip anywhere breaks the crc, exactly like CWKP
    flipped = bytearray(b)
    flipped[20] ^= 1
    assert struct.unpack(">I", bytes(flipped[-4:]))[0] != (
        zlib.crc32(bytes(flipped[:-4])) & 0xFFFFFFFF
    )


def test_shard_checkpoint_files_share_cwkp_layout():
    """Each shard's weight file is an ordinary CWKP checkpoint whose
    ``c`` is the shard's column count — the manifest ties K of them
    together. Rebuild shard files for a c=8 model split 3 ways and
    check the manifest CRCs bind the exact file bytes."""
    import zlib

    ranges = [(0, 3), (3, 6), (6, 8)]
    files = []
    for start, end in ranges:
        cl = end - start
        weights = [float(start * 16 + i) / 4.0 for i in range(cl * 16)]
        files.append(checkpoint_bytes(16, cl, 16, 6.0, 11, weights))
    manifest = shard_manifest_bytes(
        16, 8, 16, 6.0, 11,
        [
            (start, end, zlib.crc32(fb) & 0xFFFFFFFF)
            for (start, end), fb in zip(ranges, files)
        ],
    )
    # every shard file verifies against its manifest entry...
    for i, fb in enumerate(files):
        crc = struct.unpack_from(">III", manifest, 34 + i * 12)[2]
        assert crc == zlib.crc32(fb) & 0xFFFFFFFF
        assert fb[:4] == CKPT_MAGIC
    # ...and a shard file from another save generation does not
    other = checkpoint_bytes(16, 3, 16, 6.0, 12, [0.0] * 48)
    crc0 = struct.unpack_from(">III", manifest, 34)[2]
    assert crc0 != zlib.crc32(other) & 0xFFFFFFFF


# ------------------------------------------------ trace frames (obs, v3)

# Request: id=7, INFER routed to "edge" with a propagated trace id
# (flags bits 3+5) — the coordinator -> shard-host span-stitching hop.
# Shared with rust/tests/proto_frames.rs
# (golden_trace_request_bytes_match_python_twin).
GOLDEN_TRACE_REQUEST_HEX = (
    "43574b32030000002f000000000000000701280102030405060708000465"
    "646765000100000000043f800000418000004020000041800000"
)

# Request: id=12, ADMIN FETCH_TRACE — the nullary trace-ring snapshot
# verb. Shared with rust/tests/proto_frames.rs
# (golden_fetch_trace_bytes_match_python_twin).
GOLDEN_FETCH_TRACE_HEX = "43574b32030000000b000000000000000c06000b"


def golden_trace_request_bytes():
    return frame(
        T_REQUEST,
        request(
            7,
            OP_INFER,
            volleys=[dense_volley([1.0, 16.0, 2.5, 16.0])],
            model="edge",
            trace=0x0102030405060708,
        ),
    )


def golden_fetch_trace_bytes():
    return frame(T_REQUEST, request(12, OP_ADMIN, admin=cmd_fetch_trace()))


def test_golden_trace_vectors_match_contract():
    assert golden_trace_request_bytes().hex() == GOLDEN_TRACE_REQUEST_HEX
    assert golden_fetch_trace_bytes().hex() == GOLDEN_FETCH_TRACE_HEX


def test_trace_request_roundtrip():
    (ftype, payload), rest = parse_frame(golden_trace_request_bytes())
    assert (ftype, rest) == (T_REQUEST, b"")
    req = parse_request(payload)
    assert req["id"] == 7 and req["op"] == OP_INFER
    assert req["trace"] == 0x0102030405060708
    assert req["model"] == "edge"
    assert req["volleys"] == [("dense", [1.0, 16.0, 2.5, 16.0])]
    # without the flag the field is absent — unsampled requests are the
    # v2 layout exactly, which is how the bit-identity invariant holds
    bare = request(7, OP_INFER, volleys=[dense_volley([1.0])])
    assert parse_request(bare)["trace"] is None
    # trace composes with deadline (which sits before it on the wire)
    both = request(1, OP_INFER, volleys=[dense_volley([2.0])],
                   deadline_ms=50, trace=9)
    req = parse_request(both)
    assert (req["deadline_ms"], req["trace"]) == (50, 9)
    # every truncation raises instead of misparsing
    p = golden_trace_request_bytes()[9:]
    for cut in range(len(p)):
        with pytest.raises(ValueError):
            parse_request(p[:cut])


def test_fetch_trace_roundtrip():
    (_, payload), _ = parse_frame(golden_fetch_trace_bytes())
    req = parse_request(payload)
    assert req["op"] == OP_ADMIN and req["admin"] == ("fetch_trace",)
    # the verb is nullary: trailing bytes raise
    with pytest.raises(ValueError):
        parse_request(request(12, OP_ADMIN, admin=cmd_fetch_trace() + b"\x00"))


# ------------------------------------ telemetry frames (metrics/health)

# Request: id=13, ADMIN FETCH_METRICS — the nullary Prometheus-scrape
# verb. Shared with rust/tests/proto_frames.rs
# (golden_v3_bytes_match_python_twin).
GOLDEN_FETCH_METRICS_HEX = "43574b32030000000b000000000000000d06000c"

# Request: id=14, ADMIN FETCH_HEALTH — the nullary health-report verb.
# Shared with rust/tests/proto_frames.rs
# (golden_v3_bytes_match_python_twin).
GOLDEN_FETCH_HEALTH_HEX = "43574b32030000000b000000000000000e06000d"


def golden_fetch_metrics_bytes():
    return frame(T_REQUEST, request(13, OP_ADMIN, admin=cmd_fetch_metrics()))


def golden_fetch_health_bytes():
    return frame(T_REQUEST, request(14, OP_ADMIN, admin=cmd_fetch_health()))


def test_golden_telemetry_vectors_match_contract():
    assert golden_fetch_metrics_bytes().hex() == GOLDEN_FETCH_METRICS_HEX
    assert golden_fetch_health_bytes().hex() == GOLDEN_FETCH_HEALTH_HEX


def test_fetch_metrics_health_roundtrip():
    (_, payload), _ = parse_frame(golden_fetch_metrics_bytes())
    req = parse_request(payload)
    assert req["op"] == OP_ADMIN and req["admin"] == ("fetch_metrics",)
    (_, payload), _ = parse_frame(golden_fetch_health_bytes())
    req = parse_request(payload)
    assert req["op"] == OP_ADMIN and req["admin"] == ("fetch_health",)
    # both verbs are nullary: trailing bytes raise
    for builder in (cmd_fetch_metrics, cmd_fetch_health):
        with pytest.raises(ValueError):
            parse_request(request(13, OP_ADMIN, admin=builder() + b"\x00"))


# ------------------------- Prometheus exposition grammar twin (PR 10)

EXPO_KINDS = ("counter", "gauge", "summary", "histogram", "untyped")
_EXPO_NAME_CHARS = set(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:"
)


def _valid_metric_name(s):
    return (bool(s) and s[0] not in "0123456789"
            and all(c in _EXPO_NAME_CHARS for c in s))


def _parse_sample_line(line):
    """One sample: ``name[{k="v",...}] value`` with ``\\\\``, ``\\"``
    and ``\\n`` label escapes — mirroring
    rust/src/obs/telemetry.rs::parse_sample_line."""
    head, sep, value = line.rpartition(" ")
    if not sep or not head:
        raise ValueError("sample without a value: %r" % line)
    value = float(value)
    if "{" in head:
        name, _, rest = head.partition("{")
        if not rest.endswith("}"):
            raise ValueError("unterminated label set: %r" % line)
        labels, cur = [], rest[:-1]
        while cur:
            if '="' not in cur:
                raise ValueError('label without =": %r' % line)
            key, _, rest = cur.partition('="')
            if not _valid_metric_name(key):
                raise ValueError("bad label name: %r" % line)
            val, i, closed = [], 0, False
            while i < len(rest):
                c = rest[i]
                if c == "\\":
                    if i + 1 >= len(rest) or rest[i + 1] not in '\\"n':
                        raise ValueError("bad escape in label value: %r" % line)
                    val.append({"\\": "\\", '"': '"', "n": "\n"}[rest[i + 1]])
                    i += 2
                elif c == '"':
                    closed = True
                    i += 1
                    break
                else:
                    val.append(c)
                    i += 1
            if not closed:
                raise ValueError("unterminated label value: %r" % line)
            labels.append((key, "".join(val)))
            cur = rest[i:]
            if cur.startswith(","):
                cur = cur[1:]
            elif cur:
                raise ValueError("junk between labels: %r" % line)
    else:
        name, labels = head, []
    if not _valid_metric_name(name):
        raise ValueError("bad metric name: %r" % line)
    return name, labels, value


def parse_exposition(text):
    """Twin of rust's ``telemetry::parse_exposition``: every comment
    must be a well-formed HELP/TYPE, every sample's family must be
    TYPE-declared before it appears (``_sum``/``_count`` ride their
    typed summary family), and anything else raises."""
    typed, out = set(), []
    for line in text.split("\n"):
        if not line:
            continue
        if line.startswith("# "):
            parts = line[2:].split(" ", 2)
            if (len(parts) < 3 or not _valid_metric_name(parts[1])
                    or not parts[2]):
                raise ValueError("bad comment: %r" % line)
            kw, name, tail = parts
            if kw == "TYPE":
                if tail not in EXPO_KINDS:
                    raise ValueError("unknown TYPE %r: %r" % (tail, line))
                typed.add(name)
            elif kw != "HELP":
                raise ValueError("unknown comment keyword %r" % kw)
            continue
        if line.startswith("#"):
            raise ValueError("bad comment: %r" % line)
        name, labels, value = _parse_sample_line(line)
        fam = name
        for suffix in ("_sum", "_count"):
            stem = name[: -len(suffix)]
            if name.endswith(suffix) and stem in typed:
                fam = stem
                break
        if fam not in typed:
            raise ValueError("sample %r has no TYPE declaration" % name)
        out.append((name, labels, value))
    return out


# Pinned byte-for-byte against rust/src/obs/telemetry.rs
# (golden_exposition_matches_python_twin): the exposition for a
# snapshot holding {requests=12, model.edge.requests=3, model.edge.n=16,
# replication_lag_generations=1} plus a request_latency histogram
# {count=2, mean=50.0, p50=32, p95=64, p99=64, max=80} — families
# sorted by name, counters suffixed _total, gauges from the
# GAUGE_ROWS table, hists as _us summaries.
GOLDEN_EXPOSITION = (
    "# HELP catwalk_model_n stats row n\n"
    "# TYPE catwalk_model_n gauge\n"
    'catwalk_model_n{model="edge"} 16\n'
    "# HELP catwalk_model_requests_total stats row requests\n"
    "# TYPE catwalk_model_requests_total counter\n"
    'catwalk_model_requests_total{model="edge"} 3\n'
    "# HELP catwalk_replication_lag_generations stats row "
    "replication_lag_generations\n"
    "# TYPE catwalk_replication_lag_generations gauge\n"
    "catwalk_replication_lag_generations 1\n"
    "# HELP catwalk_request_latency_us latency summary request_latency\n"
    "# TYPE catwalk_request_latency_us summary\n"
    'catwalk_request_latency_us{quantile="0.5"} 32\n'
    'catwalk_request_latency_us{quantile="0.95"} 64\n'
    'catwalk_request_latency_us{quantile="0.99"} 64\n'
    'catwalk_request_latency_us{quantile="1"} 80\n'
    "catwalk_request_latency_us_sum 100\n"
    "catwalk_request_latency_us_count 2\n"
    "# HELP catwalk_requests_total stats row requests\n"
    "# TYPE catwalk_requests_total counter\n"
    "catwalk_requests_total 12\n"
)


def test_exposition_golden_parses_under_pinned_grammar():
    samples = parse_exposition(GOLDEN_EXPOSITION)
    assert len(samples) == 10
    assert samples[0] == ("catwalk_model_n", [("model", "edge")], 16.0)
    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    assert by_name["catwalk_requests_total"] == [([], 12.0)]
    assert by_name["catwalk_model_requests_total"] == [
        ([("model", "edge")], 3.0)
    ]
    # the summary carries its quantile series plus _sum/_count riders
    quantiles = [(dict(l)["quantile"], v)
                 for l, v in by_name["catwalk_request_latency_us"]]
    assert quantiles == [("0.5", 32.0), ("0.95", 64.0),
                         ("0.99", 64.0), ("1", 80.0)]
    assert by_name["catwalk_request_latency_us_sum"] == [([], 100.0)]
    assert by_name["catwalk_request_latency_us_count"] == [([], 2.0)]


def test_exposition_grammar_rejects_malformed_lines():
    ok_type = "# TYPE m counter\n"
    bad = [
        # a sample whose family was never TYPE-declared
        "m 1\n",
        # _count without a typed family does not ride anything
        ok_type + "other_count 1\n",
        # comments must be well-formed HELP/TYPE
        "# TYPE m bogus\nm 1\n",
        "# NOTE m counter\nm 1\n",
        "# TYPE m\nm 1\n",
        "#m 1\n",
        # metric/label name and label syntax errors
        ok_type + "1m 2\n",
        ok_type + 'm{0k="v"} 1\n',
        ok_type + 'm{k="v" 1\n',
        ok_type + 'm{k="v"x="y"} 1\n',
        ok_type + 'm{k="\\q"} 1\n',
        ok_type + 'm{k="v} 1\n',
        # a value must exist and be a number
        ok_type + "m\n",
        ok_type + "m one\n",
    ]
    for text in bad:
        with pytest.raises(ValueError):
            parse_exposition(text)
    # the well-formed prefix alone is fine
    assert parse_exposition(ok_type + "m 1\n") == [("m", [], 1.0)]


def test_stats_identity_rows_are_additive():
    """PR 10 adds ``uptime_secs``, ``start_epoch_secs`` and
    ``proto_version`` rows to the aggregate STATS body without bumping
    schema=2: they are ordinary counter rows, so a forward-compat
    reader picks them up — and their presence never changes what it
    extracts from the pre-existing rows."""
    base = [
        "counter.model.edge.requests=3",
        "counter.requests=12",
        "hist.request_latency.count=2",
        "hist.request_latency.p50_us=32",
        "schema=2",
    ]
    identity = [
        "counter.proto_version=3",
        "counter.start_epoch_secs=1754600000",
        "counter.uptime_secs=42",
    ]
    plain = parse_stats_kv("\n".join(sorted(base)) + "\n")
    grown = parse_stats_kv("\n".join(sorted(base + identity)) + "\n")
    counters, hists = grown
    assert counters["uptime_secs"] == 42
    assert counters["start_epoch_secs"] == 1754600000
    assert counters["proto_version"] == 3
    # dropping the identity rows recovers the original parse exactly
    for key in ("uptime_secs", "start_epoch_secs", "proto_version"):
        del counters[key]
    assert (counters, hists) == plain


# ------------------------------------------- trace capture twin (CWKT)

TRACE_MAGIC = b"CWKT"
TRACE_SCHEMA = 1
TRACE_RECORD_LEN = 30

# Stage ids and span flags, mirroring rust/src/obs/mod.rs.
(STAGE_DECODE, STAGE_ADMISSION, STAGE_QUEUE_WAIT, STAGE_KERNEL_EXEC,
 STAGE_SCATTER, STAGE_GATHER, STAGE_RPC, STAGE_REPLICATE,
 STAGE_CHECKPOINT, STAGE_REQUEST) = range(10)
SPAN_ERROR, SPAN_SLOW, SPAN_BUSY, SPAN_EXPIRED = 1, 2, 4, 8

# Shared with rust/src/obs/mod.rs (golden_cwkt_bytes_match_python_twin):
# two spans of trace 7 — KernelExec (tag=2, start 100 us, dur 250 us)
# and the closing Request span flagged SLOW (start 90 us, dur 400 us).
GOLDEN_TRACE_CAPTURE_HEX = (
    "43574b54000100000002"
    "0000000000000007030000000002000000000000006400000000000000fa"
    "0000000000000007090200000000000000000000005a0000000000000190"
    "8278446e"
)


def trace_record(trace_id, stage, flags, tag, start_us, dur_us):
    """One 30-byte span record: id u64 | stage u8 | flags u8 | tag u32
    | start_us u64 | dur_us u64."""
    return struct.pack(">QBBIQQ", trace_id, stage, flags, tag,
                       start_us, dur_us)


def trace_capture_bytes(records):
    """``obs/mod.rs`` CWKT layout: magic | schema u16 | count u32
    | count records | crc32."""
    import zlib

    p = TRACE_MAGIC + struct.pack(">HI", TRACE_SCHEMA, len(records))
    p += b"".join(records)
    return p + struct.pack(">I", zlib.crc32(p) & 0xFFFFFFFF)


def parse_trace_capture(b):
    """Decode a CWKT blob exactly the way rust's decode_traces does:
    exact length from the count field, then the trailing crc."""
    import zlib

    if len(b) < 14 or b[:4] != TRACE_MAGIC:
        raise ValueError("bad CWKT header")
    schema, count = struct.unpack_from(">HI", b, 4)
    if schema != TRACE_SCHEMA:
        raise ValueError("unknown CWKT schema %d" % schema)
    if len(b) != 14 + TRACE_RECORD_LEN * count:
        raise ValueError("CWKT length mismatch")
    if struct.unpack(">I", b[-4:])[0] != zlib.crc32(b[:-4]) & 0xFFFFFFFF:
        raise ValueError("CWKT crc mismatch")
    recs = []
    for i in range(count):
        rec = struct.unpack_from(">QBBIQQ", b, 10 + TRACE_RECORD_LEN * i)
        if rec[1] > STAGE_REQUEST:
            raise ValueError("unknown stage %d" % rec[1])
        recs.append(rec)
    return recs


def test_trace_capture_golden_bytes():
    b = trace_capture_bytes([
        trace_record(7, STAGE_KERNEL_EXEC, 0, 2, 100, 250),
        trace_record(7, STAGE_REQUEST, SPAN_SLOW, 0, 90, 400),
    ])
    assert b.hex() == GOLDEN_TRACE_CAPTURE_HEX
    # fixed header (10) + 2 records + crc
    assert len(b) == 10 + 2 * TRACE_RECORD_LEN + 4
    import zlib

    stored = struct.unpack(">I", b[-4:])[0]
    assert stored == zlib.crc32(b[:-4]) & 0xFFFFFFFF
    recs = parse_trace_capture(b)
    assert recs == [
        (7, STAGE_KERNEL_EXEC, 0, 2, 100, 250),
        (7, STAGE_REQUEST, SPAN_SLOW, 0, 90, 400),
    ]


def test_trace_capture_rejects_truncation_and_bit_flips():
    b = trace_capture_bytes([
        trace_record(7, STAGE_KERNEL_EXEC, 0, 2, 100, 250),
        trace_record(7, STAGE_REQUEST, SPAN_SLOW, 0, 90, 400),
    ])
    # every truncation raises (the count field fixes the exact length)
    for cut in range(len(b)):
        with pytest.raises(ValueError):
            parse_trace_capture(b[:cut])
    # ...and so do trailing bytes
    with pytest.raises(ValueError):
        parse_trace_capture(b + b"\x00")
    # a single bit flip anywhere is rejected: magic/schema gates, the
    # count -> exact-length check, or the trailing crc
    for byte in range(len(b)):
        for bit in range(8):
            flipped = bytearray(b)
            flipped[byte] ^= 1 << bit
            with pytest.raises(ValueError):
                parse_trace_capture(bytes(flipped))
    # an empty capture is representable and round-trips
    assert parse_trace_capture(trace_capture_bytes([])) == []


# ------------------------------------- STATS forward-compat (schema row)

KNOWN_HIST_FIELDS = ("count", "max_us", "mean_us", "p50_us", "p95_us",
                     "p99_us")


def parse_stats_kv(body):
    """A skip-unknown STATS reader mirroring rust's StatsSnapshot
    parser: unknown top-level prefixes are ignored wholesale, and
    unknown ``hist.*`` fields are skipped *before* any entry is
    created, so a novel field name can never conjure an empty
    histogram."""
    counters, hists = {}, {}
    for line in body.splitlines():
        if not line:
            continue
        if "=" not in line:
            raise ValueError("bad stats row %r" % line)
        key, value = line.split("=", 1)
        if key == "schema":
            int(value)
        elif key.startswith("counter."):
            counters[key[len("counter."):]] = int(value)
        elif key.startswith("hist."):
            name, _, field = key[len("hist."):].rpartition(".")
            if not name or field not in KNOWN_HIST_FIELDS:
                continue
            hists.setdefault(name, {})[field] = (
                float(value) if field == "mean_us" else int(value)
            )
        # any other prefix: a future schema row — skipped
    return counters, hists


def test_stats_parser_ignores_unknown_rows():
    """Property test twin of rust's prop_unknown_rows_never_change_the_
    parse: splicing arbitrary unknown rows (future top-level prefixes
    and novel hist fields) into a STATS body never changes what a
    schema-1 reader extracts from the known rows."""
    import random

    rng = random.Random(0xC4A757A7)
    prefixes = ["future", "gauge", "trace", "meta", "qos2"]
    hist_fields = ["p999_us", "stddev_us", "buckets", "v2count"]
    for _ in range(50):
        known = [
            "schema=2",
            "counter.requests=%d" % rng.randrange(1000),
            "counter.model.edge.requests=%d" % rng.randrange(1000),
            "counter.model.dist.shard.0.rpc_errors=%d" % rng.randrange(9),
            "hist.lat.count=%d" % rng.randrange(1000),
            "hist.lat.p50_us=%d" % rng.randrange(1000),
            "hist.model.dist.shard.1.rpc.p99_us=%d" % rng.randrange(1000),
        ]
        noise = []
        for _ in range(rng.randrange(1, 6)):
            if rng.random() < 0.5:
                noise.append("%s.row%d=%d" % (rng.choice(prefixes),
                                              rng.randrange(9),
                                              rng.randrange(1000)))
            else:
                noise.append("hist.lat.%s=%d" % (rng.choice(hist_fields),
                                                 rng.randrange(1000)))
            if rng.random() < 0.3:
                noise.append("hist.novel%d.%s=%d" % (
                    rng.randrange(9), rng.choice(hist_fields),
                    rng.randrange(1000)))
        noisy = sorted(known + noise)
        clean = parse_stats_kv("\n".join(sorted(known)) + "\n")
        dirty = parse_stats_kv("\n".join(noisy) + "\n")
        assert clean == dirty
        # a novel hist name carrying only unknown fields must not
        # appear as an empty entry
        _, dirty_hists = dirty
        assert all(not h or any(f in KNOWN_HIST_FIELDS for f in h)
                   for h in dirty_hists.values())
        assert not any(n.startswith("novel") for n in dirty_hists)
