"""Wire-level twin of the v2 framed protocol (``rust/src/proto/frame.rs``).

Crafts raw v2 frames with ``struct`` against the documented layout
(README "Serving protocol" / DESIGN.md §2.2) and checks them three ways:

1. **Golden vectors** — byte-identical constants asserted here *and* in
   ``rust/tests/proto_frames.rs``; they are the cross-language contract.
   If either side changes the layout, exactly one of the two suites
   breaks.
2. **Round-trips** — the twin codec decodes what it encodes.
3. **Malformed frames** — truncated header, bad magic, oversized
   length, unknown version/op/repr all raise instead of misparsing.

Layout (all integers big-endian, f32 = IEEE-754 bits big-endian):

    frame    := magic "CWK2" | type u8 | len u32 | payload[len]
    type     := 1 HELLO | 2 ACK | 3 REQUEST | 4 RESPONSE
    HELLO    := min_version u16 | max_version u16
    ACK      := version u16 | n u32 | c u32 | t_max u32
    REQUEST  := id u64 | op u8 | flags u8 | [deadline_ms u32]
                | nvolleys u16 | volley*
    volley   := 0 | n u32 | n*f32            (dense)
              | 1 | n u32 | nnz u32 | nnz*(line u32, time f32)
    RESPONSE := id u64 | status u8 | body
    RESULTS  := count u16 | (winner i32 | c u32 | c*f32)*
"""

import struct

import pytest

MAGIC = b"CWK2"
VERSION = 2
MAX_PAYLOAD = 1 << 24

T_HELLO, T_ACK, T_REQUEST, T_RESPONSE = 1, 2, 3, 4
OP_INFER, OP_LEARN, OP_STATS, OP_PING, OP_QUIT = 1, 2, 3, 4, 5
FLAG_SPARSE_REPLY, FLAG_DEADLINE, FLAG_COUNTERS_ONLY = 1, 2, 4
ST_RESULTS, ST_STATS, ST_PONG, ST_BYE, ST_ERROR = 0, 1, 2, 3, 4


# ----------------------------------------------------------- twin codec


def frame(ftype, payload):
    assert len(payload) <= MAX_PAYLOAD
    return MAGIC + struct.pack(">BI", ftype, len(payload)) + payload


def parse_frame(buf):
    """Returns ((type, payload), remaining). Raises ValueError on bad bytes."""
    if len(buf) < 9:
        raise ValueError("truncated frame header")
    if buf[:4] != MAGIC:
        raise ValueError("bad magic %r" % buf[:4])
    ftype, ln = struct.unpack(">BI", buf[4:9])
    if ftype not in (T_HELLO, T_ACK, T_REQUEST, T_RESPONSE):
        raise ValueError("unknown frame type %d" % ftype)
    if ln > MAX_PAYLOAD:
        raise ValueError("oversized frame: %d" % ln)
    if len(buf) < 9 + ln:
        raise ValueError("truncated frame payload")
    return (ftype, buf[9 : 9 + ln]), buf[9 + ln :]


def hello(min_version=VERSION, max_version=VERSION):
    return struct.pack(">HH", min_version, max_version)


def parse_ack(payload):
    if len(payload) != 14:
        raise ValueError("bad ACK length %d" % len(payload))
    version, n, c, t_max = struct.unpack(">HIII", payload)
    if version != VERSION:
        raise ValueError("unknown version %d" % version)
    return {"version": version, "n": n, "c": c, "t_max": t_max}


def dense_volley(times):
    return struct.pack(">BI", 0, len(times)) + b"".join(
        struct.pack(">f", t) for t in times
    )


def sparse_volley(n, pairs):
    out = struct.pack(">BII", 1, n, len(pairs))
    for line, t in pairs:
        out += struct.pack(">If", line, t)
    return out


def request(rid, op, volleys=(), sparse_reply=False, deadline_ms=None,
            counters_only=False):
    flags = (
        (FLAG_SPARSE_REPLY if sparse_reply else 0)
        | (FLAG_DEADLINE if deadline_ms is not None else 0)
        | (FLAG_COUNTERS_ONLY if counters_only else 0)
    )
    p = struct.pack(">QBB", rid, op, flags)
    if deadline_ms is not None:
        p += struct.pack(">I", deadline_ms)
    p += struct.pack(">H", len(volleys))
    return p + b"".join(volleys)


class Cur:
    def __init__(self, b):
        self.b, self.off = b, 0

    def take(self, fmt):
        size = struct.calcsize(fmt)
        if self.off + size > len(self.b):
            raise ValueError("short payload at offset %d" % self.off)
        vals = struct.unpack_from(fmt, self.b, self.off)
        self.off += size
        return vals if len(vals) > 1 else vals[0]

    def finish(self):
        if self.off != len(self.b):
            raise ValueError("%d trailing bytes" % (len(self.b) - self.off))


def parse_request(payload):
    cur = Cur(payload)
    rid, op, flags = cur.take(">QBB")
    if op not in (OP_INFER, OP_LEARN, OP_STATS, OP_PING, OP_QUIT):
        raise ValueError("unknown op %d" % op)
    if flags & ~(FLAG_SPARSE_REPLY | FLAG_DEADLINE | FLAG_COUNTERS_ONLY):
        raise ValueError("unknown flags %#x" % flags)
    deadline = cur.take(">I") if flags & FLAG_DEADLINE else None
    volleys = []
    for _ in range(cur.take(">H")):
        repr_ = cur.take(">B")
        if repr_ == 0:
            n = cur.take(">I")
            if n * 4 > len(cur.b) - cur.off:
                raise ValueError("dense count exceeds payload")
            volleys.append(("dense", [cur.take(">f") for _ in range(n)]))
        elif repr_ == 1:
            n, nnz = cur.take(">II")
            if nnz * 8 > len(cur.b) - cur.off:
                raise ValueError("sparse count exceeds payload")
            pairs = [cur.take(">If") for _ in range(nnz)]
            if any(line >= n for line, _ in pairs):
                raise ValueError("line out of range")
            if any(a[0] >= b[0] for a, b in zip(pairs, pairs[1:])):
                raise ValueError("lines not strictly ascending")
            volleys.append(("sparse", n, pairs))
        else:
            raise ValueError("unknown volley repr %d" % repr_)
    cur.finish()
    return {
        "id": rid,
        "op": op,
        "volleys": volleys,
        "sparse_reply": bool(flags & FLAG_SPARSE_REPLY),
        "deadline_ms": deadline,
        "counters_only": bool(flags & FLAG_COUNTERS_ONLY),
    }


def response_results(rid, results):
    p = struct.pack(">QBH", rid, ST_RESULTS, len(results))
    for winner, times in results:
        p += struct.pack(">iI", winner, len(times))
        p += b"".join(struct.pack(">f", t) for t in times)
    return p


def parse_response(payload):
    cur = Cur(payload)
    rid, status = cur.take(">QB")
    if status == ST_RESULTS:
        results = []
        for _ in range(cur.take(">H")):
            winner, c = cur.take(">iI")
            if c * 4 > len(cur.b) - cur.off:
                raise ValueError("result count exceeds payload")
            results.append((winner, [cur.take(">f") for _ in range(c)]))
        cur.finish()
        return {"id": rid, "results": results}
    if status in (ST_STATS, ST_ERROR):
        body = cur.b[cur.off :].decode("utf-8")
        return {"id": rid, ("stats" if status == ST_STATS else "error"): body}
    if status in (ST_PONG, ST_BYE):
        cur.finish()
        return {"id": rid, "status": "pong" if status == ST_PONG else "bye"}
    raise ValueError("unknown response status %d" % status)


# ------------------------------------------------------- golden vectors

# The same constants appear in rust/tests/proto_frames.rs. Request:
# id=7, INFER, sparse_reply + deadline 250 ms, two volleys —
# dense [1.0, 16.0, 2.5, 16.0] and sparse n=4 {(1, 3.0)}.
GOLDEN_REQUEST_HEX = (
    "43574b32030000003600000000000000070103000000fa00020000000004"
    "3f8000004180000040200000418000000100000004000000010000000140400000"
)

# Response: id=7, one result, winner=2, times=[4.0, 16.0, 2.0].
GOLDEN_RESPONSE_HEX = (
    "43574b32040000001f000000000000000700000100000002000000034080"
    "00004180000040000000"
)

# HELLO [2,2] and ACK v2 for an n=16, c=8, t_max=16 column.
GOLDEN_HELLO_HEX = "43574b32010000000400020002"
GOLDEN_ACK_HEX = "43574b32020000000e0002000000100000000800000010"


def golden_request_bytes():
    return frame(
        T_REQUEST,
        request(
            7,
            OP_INFER,
            volleys=[
                dense_volley([1.0, 16.0, 2.5, 16.0]),
                sparse_volley(4, [(1, 3.0)]),
            ],
            sparse_reply=True,
            deadline_ms=250,
        ),
    )


def golden_response_bytes():
    return frame(T_RESPONSE, response_results(7, [(2, [4.0, 16.0, 2.0])]))


def golden_hello_bytes():
    return frame(T_HELLO, hello(2, 2))


def golden_ack_bytes():
    return frame(T_ACK, struct.pack(">HIII", VERSION, 16, 8, 16))


# ----------------------------------------------------------------- tests


def test_golden_request_bytes_match_contract():
    assert golden_request_bytes().hex() == GOLDEN_REQUEST_HEX


def test_golden_response_bytes_match_contract():
    assert golden_response_bytes().hex() == GOLDEN_RESPONSE_HEX


def test_golden_handshake_bytes_match_contract():
    assert golden_hello_bytes().hex() == GOLDEN_HELLO_HEX
    assert golden_ack_bytes().hex() == GOLDEN_ACK_HEX


def test_request_roundtrip():
    (ftype, payload), rest = parse_frame(golden_request_bytes())
    assert (ftype, rest) == (T_REQUEST, b"")
    req = parse_request(payload)
    assert req["id"] == 7
    assert req["op"] == OP_INFER
    assert req["sparse_reply"] and req["deadline_ms"] == 250
    assert not req["counters_only"]
    assert req["volleys"][0] == ("dense", [1.0, 16.0, 2.5, 16.0])
    assert req["volleys"][1] == ("sparse", 4, [(1, 3.0)])


def test_response_roundtrip_and_statuses():
    (_, payload), _ = parse_frame(golden_response_bytes())
    resp = parse_response(payload)
    assert resp == {"id": 7, "results": [(2, [4.0, 16.0, 2.0])]}

    # winner -1 = silent; two's-complement i32 on the wire
    p = response_results(9, [(-1, [16.0])])
    assert parse_response(p)["results"] == [(-1, [16.0])]

    stats = struct.pack(">QB", 3, ST_STATS) + b"counter.requests=5\nschema=1\n"
    assert parse_response(stats)["stats"] == "counter.requests=5\nschema=1\n"
    err = struct.pack(">QB", 3, ST_ERROR) + "boom ✗".encode("utf-8")
    assert parse_response(err)["error"] == "boom ✗"
    assert parse_response(struct.pack(">QB", 1, ST_PONG))["status"] == "pong"
    assert parse_response(struct.pack(">QB", 1, ST_BYE))["status"] == "bye"


def test_ack_parses_geometry():
    (ftype, payload), _ = parse_frame(golden_ack_bytes())
    assert ftype == T_ACK
    assert parse_ack(payload) == {"version": 2, "n": 16, "c": 8, "t_max": 16}
    with pytest.raises(ValueError):
        parse_ack(struct.pack(">HIII", 9, 1, 1, 1))  # unknown version
    with pytest.raises(ValueError):
        parse_ack(b"\x00\x02")  # truncated


def test_frames_concatenate_for_pipelining():
    buf = golden_request_bytes() * 3
    seen = []
    while buf:
        (ftype, payload), buf = parse_frame(buf)
        seen.append(ftype)
    assert seen == [T_REQUEST] * 3


@pytest.mark.parametrize(
    "mutate",
    [
        lambda b: b[:3],  # truncated header
        lambda b: b[:11],  # truncated payload
        lambda b: b"XWK2" + b[4:],  # bad magic
        lambda b: b[:4] + struct.pack(">BI", 9, 0),  # unknown frame type
        lambda b: b[:4] + struct.pack(">BI", T_REQUEST, MAX_PAYLOAD + 1),  # oversized
    ],
)
def test_malformed_frames_raise(mutate):
    with pytest.raises(ValueError):
        parse_frame(mutate(golden_request_bytes()))


def test_malformed_request_payloads_raise():
    good = request(1, OP_INFER, [dense_volley([1.0, 2.0])])
    parse_request(good)  # sanity
    for cut in range(len(good)):
        with pytest.raises(ValueError):
            parse_request(good[:cut])
    with pytest.raises(ValueError):
        parse_request(good + b"\x00")  # trailing bytes
    with pytest.raises(ValueError):
        parse_request(request(1, 99, []))  # unknown op
    bad_flags = struct.pack(">QBB", 1, OP_PING, 0x80) + struct.pack(">H", 0)
    with pytest.raises(ValueError):
        parse_request(bad_flags)
    # hostile dense count must not be trusted
    huge = struct.pack(">QBB", 1, OP_INFER, 0) + struct.pack(">H", 1)
    huge += struct.pack(">BI", 0, 0xFFFFFFFF)
    with pytest.raises(ValueError):
        parse_request(huge)
    # sparse invariants: out-of-range line, unsorted lines
    with pytest.raises(ValueError):
        parse_request(request(1, OP_INFER, [sparse_volley(4, [(9, 1.0)])]))
    with pytest.raises(ValueError):
        parse_request(
            request(1, OP_INFER, [sparse_volley(4, [(2, 1.0), (1, 1.0)])])
        )


def test_stats_kv_schema_shape():
    """The STATS body is line-oriented key=value, sorted by key."""
    body = "counter.requests=5\nhist.lat.p50_us=64\nschema=1\n"
    lines = body.strip().splitlines()
    assert lines == sorted(lines)
    parsed = dict(line.split("=", 1) for line in lines)
    assert parsed["schema"] == "1"
    assert int(parsed["counter.requests"]) == 5
