"""Compare-and-swap network construction for the Pallas top-k kernel.

This mirrors `rust/src/topk/mod.rs` (`tournament_network` + Algorithm-1
pruning): the compile path must be self-contained in Python so that
`make artifacts` never depends on a prior Rust build. Cross-language
conformance is pinned two ways:

* pytest checks the kernel against the pure-jnp oracle (`ref.py`);
* the Rust integration suite executes the AOT'd kernel through PJRT and
  compares it against the gate-level netlist simulation of the same
  selector.

Orientation matches the hardware: comparator ``(top, bot)`` with
``top < bot`` sends the OR (max / earlier-rising pulse) to ``bot``; after
the network, the k selected lanes are the *bottom* k (``n-k .. n-1``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Literal, Tuple

UnitKind = Literal["full", "max", "min"]


@dataclass(frozen=True)
class Unit:
    top: int
    bot: int
    kind: UnitKind


def _optimal_sorter(n: int) -> List[Tuple[int, int]]:
    """Best-known sorting networks for tiny n (see rust sorters::optimal)."""
    if n == 2:
        return [(0, 1)]
    if n == 4:
        return [(0, 1), (2, 3), (0, 2), (1, 3), (1, 2)]
    if n == 8:
        return [
            (0, 1), (2, 3), (4, 5), (6, 7),
            (0, 2), (1, 3), (4, 6), (5, 7),
            (1, 2), (5, 6),
            (0, 4), (1, 5), (2, 6), (3, 7),
            (2, 4), (3, 5),
            (1, 2), (3, 4), (5, 6),
        ]
    return _odd_even_sorter(n)


def _odd_even_sorter(n: int) -> List[Tuple[int, int]]:
    out: List[Tuple[int, int]] = []

    def sort(lo: int, m: int) -> None:
        if m <= 1:
            return
        h = m // 2
        sort(lo, h)
        sort(lo + h, h)
        merge(lo, m, 1)

    def merge(lo: int, m: int, r: int) -> None:
        step = r * 2
        if step < m:
            merge(lo, m, step)
            merge(lo + r, m, step)
            i = lo + r
            while i + r < lo + m:
                out.append((i, i + r))
                i += step
        else:
            out.append((lo, lo + r))

    sort(0, n)
    return out


def _odd_even_merge_pairs(n: int) -> List[Tuple[int, int]]:
    out: List[Tuple[int, int]] = []

    def rec(lo: int, m: int, r: int) -> None:
        step = r * 2
        if step < m:
            rec(lo, m, step)
            rec(lo + r, m, step)
            i = lo + r
            while i + r < lo + m:
                out.append((i, i + r))
                i += step
        else:
            out.append((lo, lo + r))

    rec(0, n, 1)
    return out


def tournament_network(n: int, k: int) -> List[Tuple[int, int]]:
    """Odd-even-merge tournament selection network (unpruned)."""
    if n & (n - 1) or k & (k - 1) or not (1 <= k <= n) or n < 2:
        raise ValueError(f"need powers of two with 1 <= k <= n, got n={n} k={k}")
    out: List[Tuple[int, int]] = []

    def rec(lo: int, size: int) -> None:
        if size == k:
            if k >= 2:
                for a, b in _optimal_sorter(k):
                    out.append((lo + a, lo + b))
            return
        half = size // 2
        rec(lo, half)
        rec(lo + half, half)

        def phys(v: int) -> int:
            return lo + half - k + v if v < k else lo + size - k + (v - k)

        for a, b in _odd_even_merge_pairs(2 * k):
            out.append((phys(a), phys(b)))

    rec(0, n)
    return out


def prune(comparators: List[Tuple[int, int]], n: int, k: int) -> List[Unit]:
    """Algorithm 1: backward liveness + half-unit analysis."""
    live = [False] * n
    for lane in range(n - k, n):
        live[lane] = True
    mandatory: List[Tuple[int, int]] = []
    for t, b in reversed(comparators):
        if live[t] or live[b]:
            mandatory.append((t, b))
            live[t] = True
            live[b] = True
    mandatory.reverse()

    units: List[Unit] = []
    for idx, (t, b) in enumerate(mandatory):
        top_used = t >= n - k
        bot_used = b >= n - k
        for lt, lb in mandatory[idx + 1:]:
            if t in (lt, lb):
                top_used = True
            if b in (lt, lb):
                bot_used = True
            if top_used and bot_used:
                break
        kind: UnitKind = (
            "full" if (top_used and bot_used) else ("max" if bot_used else "min")
        )
        units.append(Unit(t, b, kind))
    return units


def catwalk_schedule(n: int, k: int) -> List[Unit]:
    """The selector the Catwalk dendrite instantiates (rust
    ``TopkSelector::catwalk``)."""
    return prune(tournament_network(n, k), n, k)


def gate_count(units: List[Unit]) -> int:
    return sum(2 if u.kind == "full" else 1 for u in units)
