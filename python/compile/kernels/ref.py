"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signals of the compile path: every kernel
in this package must match its reference bit-for-bit (top-k) or exactly
in integer arithmetic (RNL column) under the pytest + hypothesis sweeps
in ``python/tests/``.

Shapes and conventions (shared with the kernels and the Rust runtime):

* waveforms: ``[B, n, T]`` float32 in {0.0, 1.0}; lane = dendrite input,
  T = clock cycles of one gamma window.
* spike times: ``[B, n]`` float32; a value ``>= t_max`` means "no spike"
  (the temporal-code infinity of paper Fig. 2a).
* weights: ``[C, n]`` float32 in ``[0, 7]`` (3-bit RNL response widths).
"""

from __future__ import annotations

import jax.numpy as jnp


def topk_wave_ref(waves: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-cycle top-k selection oracle.

    A compare-and-swap network applied bitwise per cycle sorts each
    cycle's bit column, so tap ``j`` (j = 0 is the highest kept lane,
    j = k-1 the bottom lane) carries a 1 iff at least ``k - j`` lanes are
    high that cycle.

    waves: [B, n, T] in {0,1} -> [B, k, T].
    """
    count = jnp.sum(waves, axis=1, keepdims=True)  # [B, 1, T]
    need = jnp.arange(k, 0, -1, dtype=waves.dtype).reshape(1, k, 1)
    return (count >= need).astype(waves.dtype)


def rnl_column_ref(
    spike_times: jnp.ndarray,
    weights: jnp.ndarray,
    theta: jnp.ndarray,
    t_max: int,
    k_clip: int | None = None,
) -> jnp.ndarray:
    """SRM0-RNL column forward oracle.

    For every (batch b, column c): per cycle t the response count is
    ``sum_i [t >= s_bi and t < s_bi + w_ci]``, optionally clipped at
    ``k_clip`` (the Catwalk dendrite); the membrane potential is the
    running sum; the output spike time is the first t where it reaches
    ``theta``, else ``t_max`` (= no spike).

    spike_times: [B, n]; weights: [C, n]; theta: scalar array.
    Returns [B, C] float32 spike times in ``0..=t_max``.
    """
    s = spike_times[:, None, :, None]  # [B,1,n,1]
    w = weights[None, :, :, None]  # [1,C,n,1]
    t = jnp.arange(t_max, dtype=spike_times.dtype)  # [T]
    active = (t >= s) & (t < s + w)  # [B,C,n,T]
    count = jnp.sum(active.astype(spike_times.dtype), axis=2)  # [B,C,T]
    if k_clip is not None:
        count = jnp.minimum(count, float(k_clip))
    pot = jnp.cumsum(count, axis=-1)  # [B,C,T]
    fired = pot >= theta  # [B,C,T]
    # first firing cycle, t_max if none
    t_idx = jnp.arange(t_max, dtype=spike_times.dtype)
    times = jnp.where(fired, t_idx, float(t_max))
    return jnp.min(times, axis=-1)


def wta_ref(out_times: jnp.ndarray, t_max: int) -> jnp.ndarray:
    """1-winner-take-all oracle: one-hot of the earliest-spiking column
    (lowest index breaks ties); all-zero row when no column spiked.

    out_times: [B, C] -> [B, C] float32 mask.
    """
    winner = jnp.argmin(out_times, axis=-1)  # [B]
    any_spike = jnp.min(out_times, axis=-1) < t_max  # [B]
    onehot = jnp.zeros_like(out_times).at[jnp.arange(out_times.shape[0]), winner].set(1.0)
    return onehot * any_spike[:, None].astype(out_times.dtype)


def stdp_ref(
    weights: jnp.ndarray,
    in_times: jnp.ndarray,
    out_times: jnp.ndarray,
    winner_mask: jnp.ndarray,
    t_max: int,
    w_max: float = 7.0,
    mu_capture: float = 0.30,
    mu_backoff: float = 0.20,
    mu_search: float = 0.02,
) -> jnp.ndarray:
    """Expected-value TNN STDP oracle (Smith-style rules, winner-gated).

    For the winner column y with output time t_y and each input x with
    time t_x (>= t_max means silent):

    * x spiked and t_x <= t_y  -> capture: w += mu_capture * (w_max - w)
    * x spiked and t_x >  t_y  -> backoff: w -= mu_backoff * w
    * x silent and y fired     -> backoff: w -= mu_backoff * w
    * x spiked and y silent    -> search:  w += mu_search * (w_max - w)

    Updates are averaged over the batch; non-winner columns are untouched.
    weights [C,n], in_times [B,n], out_times [B,C], winner_mask [B,C].
    """
    x_spk = (in_times < t_max)[:, None, :]  # [B,1,n]
    y_spk = (out_times < t_max)[:, :, None]  # [B,C,1]
    t_x = in_times[:, None, :]
    t_y = out_times[:, :, None]
    w = weights[None, :, :]  # [1,C,n]

    capture = x_spk & y_spk & (t_x <= t_y)
    backoff = (x_spk & y_spk & (t_x > t_y)) | (~x_spk & y_spk)
    search = x_spk & ~y_spk

    delta = (
        capture.astype(w.dtype) * mu_capture * (w_max - w)
        - backoff.astype(w.dtype) * mu_backoff * w
        + search.astype(w.dtype) * mu_search * (w_max - w)
    )  # [B,C,n]
    # Winner-gated; when no column fired at all, every column searches
    # (otherwise a silent network could never become responsive).
    no_spike_row = (jnp.min(out_times, axis=-1) >= t_max).astype(w.dtype)[:, None]
    gate = jnp.clip(winner_mask + no_spike_row, 0.0, 1.0)
    gated = delta * gate[:, :, None]
    batch = jnp.mean(gated, axis=0)  # [C,n]
    return jnp.clip(weights + batch, 0.0, w_max)
