"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signals of the compile path: every kernel
in this package must match its reference bit-for-bit (top-k) or exactly
in integer arithmetic (RNL column) under the pytest + hypothesis sweeps
in ``python/tests/``.

Shapes and conventions (shared with the kernels and the Rust runtime):

* waveforms: ``[B, n, T]`` float32 in {0.0, 1.0}; lane = dendrite input,
  T = clock cycles of one gamma window.
* spike times: ``[B, n]`` float32; a value ``>= t_max`` means "no spike"
  (the temporal-code infinity of paper Fig. 2a).
* weights: ``[C, n]`` float32 in ``[0, 7]`` (3-bit RNL response widths).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def topk_wave_ref(waves: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-cycle top-k selection oracle.

    A compare-and-swap network applied bitwise per cycle sorts each
    cycle's bit column, so tap ``j`` (j = 0 is the highest kept lane,
    j = k-1 the bottom lane) carries a 1 iff at least ``k - j`` lanes are
    high that cycle.

    waves: [B, n, T] in {0,1} -> [B, k, T].
    """
    count = jnp.sum(waves, axis=1, keepdims=True)  # [B, 1, T]
    need = jnp.arange(k, 0, -1, dtype=waves.dtype).reshape(1, k, 1)
    return (count >= need).astype(waves.dtype)


def rnl_column_ref(
    spike_times: jnp.ndarray,
    weights: jnp.ndarray,
    theta: jnp.ndarray,
    t_max: int,
    k_clip: int | None = None,
) -> jnp.ndarray:
    """SRM0-RNL column forward oracle.

    For every (batch b, column c): per cycle t the response count is
    ``sum_i [t >= s_bi and t < s_bi + w_ci]``, optionally clipped at
    ``k_clip`` (the Catwalk dendrite); the membrane potential is the
    running sum; the output spike time is the first t where it reaches
    ``theta``, else ``t_max`` (= no spike).

    spike_times: [B, n]; weights: [C, n]; theta: scalar array.
    Returns [B, C] float32 spike times in ``0..=t_max``.
    """
    s = spike_times[:, None, :, None]  # [B,1,n,1]
    w = weights[None, :, :, None]  # [1,C,n,1]
    t = jnp.arange(t_max, dtype=spike_times.dtype)  # [T]
    active = (t >= s) & (t < s + w)  # [B,C,n,T]
    count = jnp.sum(active.astype(spike_times.dtype), axis=2)  # [B,C,T]
    if k_clip is not None:
        count = jnp.minimum(count, float(k_clip))
    pot = jnp.cumsum(count, axis=-1)  # [B,C,T]
    fired = pot >= theta  # [B,C,T]
    # first firing cycle, t_max if none
    t_idx = jnp.arange(t_max, dtype=spike_times.dtype)
    times = jnp.where(fired, t_idx, float(t_max))
    return jnp.min(times, axis=-1)


def dense_to_sparse(spike_times, t_max: int) -> list[list[tuple[int, float]]]:
    """Dense ``[B, n]`` spike times -> per-row sorted ``(line, time)``
    lists holding only the spiking lines (``time < t_max``; NaN counts as
    silent). The canonical sparse form of ``rust/src/volley``.
    """
    s = np.asarray(spike_times, np.float32)
    return [
        [(int(i), float(t)) for i, t in enumerate(row) if t < t_max]
        for row in s
    ]


def sparse_to_dense(spike_lists, n: int, t_max: int) -> np.ndarray:
    """Per-row ``(line, time)`` lists -> canonical dense ``[B, n]``
    float32 spike times (silent lines = exactly ``t_max``)."""
    out = np.full((len(spike_lists), n), float(t_max), np.float32)
    for b, row in enumerate(spike_lists):
        for i, t in row:
            if not 0 <= i < n:
                raise ValueError(f"line {i} out of range (n = {n})")
            out[b, i] = t
    return out


def rnl_column_sparse_ref(
    spike_lists,
    n: int,
    weights,
    theta,
    t_max: int,
    k_clip: int | None = None,
) -> np.ndarray:
    """Sparsity-aware SRM0-RNL column forward: iterates only the spiking
    lines of each volley, mirroring the historical
    ``runtime::native::rnl_forward_sparse`` in the Rust serving stack
    (whose successor is the compacted path —
    :func:`rnl_column_compacted_ref`).

    Must agree exactly with :func:`rnl_column_ref` on the canonical dense
    form of the same volleys — the per-cycle count is a sum of ones over
    exactly the lines whose ramp is active, so clipping and the running
    potential see identical values.

    spike_lists: per-row ``(line, time)`` lists (see :func:`dense_to_sparse`);
    weights: ``[C, n]``; theta: scalar (python float or any 1-element
    array). Returns ``[B, C]`` float32 first-crossing times.
    """
    w = np.asarray(weights, np.float32)
    th = float(np.asarray(theta, np.float32).reshape(-1)[0])
    c = w.shape[0]
    out = np.full((len(spike_lists), c), float(t_max), np.float32)
    for b, row in enumerate(spike_lists):
        active = [(i, t) for i, t in row if t < t_max]
        for ci in range(c):
            pot = np.float32(0.0)
            for t in range(t_max):
                count = sum(1 for i, s in active if s <= t < s + w[ci, i])
                if k_clip is not None:
                    count = min(count, k_clip)
                pot += np.float32(count)
                if pot >= th:
                    out[b, ci] = float(t)
                    break
    return out


def rnl_column_compacted_ref(
    spike_times,
    weights,
    theta,
    t_max: int,
    k_clip: int | None = None,
) -> np.ndarray:
    """Software-Catwalk SRM0-RNL forward: the Python twin of the Rust
    ``KernelPlan`` compacted path (``rust/src/runtime/plan.rs``,
    DESIGN.md §2.5).

    Once per batch, every volley's scattered ``(line, time)`` entries are
    compacted into a contiguous sorted-by-line dense prefix (the paper's
    unary top-k relocation, done in software); the column-major sweep then
    gathers each run's weights once (``wk = w[c, lines]``) and scans two
    dense arrays per cycle — no per-cycle ``w[line]`` indirection.

    Must agree exactly with :func:`rnl_column_ref`: the per-cycle count is
    a sum of ones over exactly the lines whose ramp is active, so count,
    clip, and the running potential take identical values regardless of
    whether silent lines participate (they count 0) or are absent.

    spike_times: ``[B, n]`` (``>= t_max`` or NaN = silent); weights
    ``[C, n]``; theta scalar. Returns ``[B, C]`` float32 first-crossing
    times.
    """
    s = np.asarray(spike_times, np.float32)
    w = np.asarray(weights, np.float32)
    th = float(np.asarray(theta, np.float32).reshape(-1)[0])
    b, c = s.shape[0], w.shape[0]
    # relocation stage: one CSR-style compaction per batch
    lines = [np.flatnonzero(row < t_max) for row in s]
    times = [row[idx] for row, idx in zip(s, lines)]
    out = np.full((b, c), float(t_max), np.float32)
    for ci in range(c):  # column-major: one weight row serves the batch
        for bi in range(b):
            wk = w[ci, lines[bi]]  # gather once per (column, row)
            tk = times[bi]
            pot = np.float32(0.0)
            for t in range(t_max):
                count = int(np.count_nonzero((tk <= t) & (t < tk + wk)))
                if k_clip is not None:
                    count = min(count, k_clip)
                pot += np.float32(count)
                if pot >= th:
                    out[bi, ci] = float(t)
                    break
    return out


def wta_ref(out_times: jnp.ndarray, t_max: int) -> jnp.ndarray:
    """1-winner-take-all oracle: one-hot of the earliest-spiking column
    (lowest index breaks ties); all-zero row when no column spiked.

    out_times: [B, C] -> [B, C] float32 mask.
    """
    winner = jnp.argmin(out_times, axis=-1)  # [B]
    any_spike = jnp.min(out_times, axis=-1) < t_max  # [B]
    onehot = jnp.zeros_like(out_times).at[jnp.arange(out_times.shape[0]), winner].set(1.0)
    return onehot * any_spike[:, None].astype(out_times.dtype)


def stdp_ref(
    weights: jnp.ndarray,
    in_times: jnp.ndarray,
    out_times: jnp.ndarray,
    winner_mask: jnp.ndarray,
    t_max: int,
    w_max: float = 7.0,
    mu_capture: float = 0.30,
    mu_backoff: float = 0.20,
    mu_search: float = 0.02,
) -> jnp.ndarray:
    """Expected-value TNN STDP oracle (Smith-style rules, winner-gated).

    For the winner column y with output time t_y and each input x with
    time t_x (>= t_max means silent):

    * x spiked and t_x <= t_y  -> capture: w += mu_capture * (w_max - w)
    * x spiked and t_x >  t_y  -> backoff: w -= mu_backoff * w
    * x silent and y fired     -> backoff: w -= mu_backoff * w
    * x spiked and y silent    -> search:  w += mu_search * (w_max - w)

    Updates are averaged over the batch; non-winner columns are untouched.
    weights [C,n], in_times [B,n], out_times [B,C], winner_mask [B,C].
    """
    x_spk = (in_times < t_max)[:, None, :]  # [B,1,n]
    y_spk = (out_times < t_max)[:, :, None]  # [B,C,1]
    t_x = in_times[:, None, :]
    t_y = out_times[:, :, None]
    w = weights[None, :, :]  # [1,C,n]

    capture = x_spk & y_spk & (t_x <= t_y)
    backoff = (x_spk & y_spk & (t_x > t_y)) | (~x_spk & y_spk)
    search = x_spk & ~y_spk

    delta = (
        capture.astype(w.dtype) * mu_capture * (w_max - w)
        - backoff.astype(w.dtype) * mu_backoff * w
        + search.astype(w.dtype) * mu_search * (w_max - w)
    )  # [B,C,n]
    # Winner-gated; when no column fired at all, every column searches
    # (otherwise a silent network could never become responsive).
    no_spike_row = (jnp.min(out_times, axis=-1) >= t_max).astype(w.dtype)[:, None]
    gate = jnp.clip(winner_mask + no_spike_row, 0.0, 1.0)
    gated = delta * gate[:, :, None]
    batch = jnp.mean(gated, axis=0)  # [C,n]
    return jnp.clip(weights + batch, 0.0, w_max)
