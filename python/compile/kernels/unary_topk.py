"""L1 Pallas kernel: unary top-k over batched temporal waveforms.

The kernel evaluates the Catwalk selection network (compare-and-swap
units from :mod:`.networks`) bitwise per clock cycle on a batch of
waveforms — the data-parallel form of the paper's dendrite hardware.
AND/OR on {0,1}-valued float lanes become ``minimum``/``maximum`` on the
VPU; the unit list is a compile-time constant, so the network unrolls
into a fixed elementwise schedule with no gather/scatter.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the batch;
one block of ``[block_b, n, T]`` float32 sits in VMEM (e.g. 256×64×16×4 B
= 1 MiB), lanes live along the sublane dimension, and each comparator
layer is a pair of vector min/max ops. ``interpret=True`` everywhere —
the CPU PJRT plugin cannot execute Mosaic custom-calls; real-TPU numbers
are estimated from the BlockSpec footprint in DESIGN.md.
"""

from __future__ import annotations

from functools import partial
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .networks import Unit, catwalk_schedule


def _topk_kernel_body(x_ref, o_ref, *, units: List[Unit], n: int, k: int):
    x = x_ref[...]  # [block_b, n, T]
    lanes = [x[:, i, :] for i in range(n)]
    for u in units:
        a = lanes[u.top]
        b = lanes[u.bot]
        if u.kind in ("full", "min"):
            mn = jnp.minimum(a, b)
        if u.kind in ("full", "max"):
            mx = jnp.maximum(a, b)
        if u.kind in ("full", "min"):
            lanes[u.top] = mn
        if u.kind in ("full", "max"):
            lanes[u.bot] = mx
    out = jnp.stack([lanes[n - k + j] for j in range(k)], axis=1)  # [block_b,k,T]
    o_ref[...] = out


def unary_topk(waves: jnp.ndarray, k: int, *, block_b: int = 64) -> jnp.ndarray:
    """Apply the Catwalk top-k selection network per cycle.

    waves: [B, n, T] float32 in {0,1}; B must be a multiple of
    ``block_b`` (pad upstream). Returns [B, k, T]: tap j carries a 1 in a
    cycle iff at least k-j lanes were high (taps ascend toward the
    bottom lane).
    """
    b, n, t = waves.shape
    if b % block_b:
        raise ValueError(f"batch {b} not a multiple of block {block_b}")
    units = catwalk_schedule(n, k)
    body = partial(_topk_kernel_body, units=units, n=n, k=k)
    return pl.pallas_call(
        body,
        grid=(b // block_b,),
        in_specs=[pl.BlockSpec((block_b, n, t), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b, k, t), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k, t), waves.dtype),
        interpret=True,
    )(waves)


def times_to_waves(spike_times: jnp.ndarray, widths: jnp.ndarray, t_max: int) -> jnp.ndarray:
    """Expand (start, width) pulse descriptors to waveforms.

    spike_times/widths: [B, n] -> [B, n, t_max] float32. A start >= t_max
    yields an all-zero lane (no spike).
    """
    t = jnp.arange(t_max, dtype=spike_times.dtype)
    s = spike_times[..., None]
    w = widths[..., None]
    return ((t >= s) & (t < s + w)).astype(spike_times.dtype)
