"""L1 Pallas kernel: batched SRM0-RNL column forward pass.

Computes, for a batch of input volleys against every neuron (column cell)
of a TNN column, the membrane-potential integration of the ramp-no-leak
response (paper Eq. 1) and the first threshold crossing — the functional
hot loop of the TNN workload that motivates the paper's k = 2 choice.

One grid step owns a ``[block_b, n]`` tile of spike times and the whole
``[C, n]`` weight matrix (columns are small: C <= 32, n <= 64, so the
weights stay resident in VMEM across the batch sweep). Time is a static
Python loop of ``t_max`` (= 16) iterations of elementwise compare +
masked accumulate — on a real TPU this is a fully unrolled VPU schedule
with zero HBM traffic after the initial tile loads.

The optional ``k_clip`` reproduces the Catwalk dendrite: the per-cycle
response count is clamped at k before accumulation (the clipping
semantics of DESIGN.md §1.1); ``k_clip=None`` is the un-clipped
baseline dendrite.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rnl_kernel_body(s_ref, w_ref, theta_ref, o_ref, *, t_max: int, k_clip):
    s = s_ref[...]  # [block_b, n]
    w = w_ref[...]  # [C, n]
    theta = theta_ref[0, 0]
    bb = s.shape[0]
    c = w.shape[0]
    pot = jnp.zeros((bb, c), dtype=s.dtype)
    out = jnp.full((bb, c), float(t_max), dtype=s.dtype)
    s_e = s[:, None, :]  # [bb,1,n]
    w_e = w[None, :, :]  # [1,C,n]
    for t in range(t_max):
        active = (t >= s_e) & (t < s_e + w_e)  # [bb,C,n]
        count = jnp.sum(active.astype(s.dtype), axis=-1)  # [bb,C]
        if k_clip is not None:
            count = jnp.minimum(count, float(k_clip))
        pot = pot + count
        newly = (pot >= theta) & (out >= float(t_max))
        out = jnp.where(newly, float(t), out)
    o_ref[...] = out


def rnl_column(
    spike_times: jnp.ndarray,
    weights: jnp.ndarray,
    theta: jnp.ndarray,
    *,
    t_max: int = 16,
    k_clip: int | None = None,
    block_b: int = 64,
) -> jnp.ndarray:
    """First-crossing spike times of an RNL column.

    spike_times: [B, n] (>= t_max means silent), weights: [C, n],
    theta: [1, 1]. Returns [B, C] float32 times in ``0..=t_max``.
    """
    b, n = spike_times.shape
    c, n2 = weights.shape
    if n != n2:
        raise ValueError(f"inputs {n} != weight fan-in {n2}")
    if b % block_b:
        raise ValueError(f"batch {b} not a multiple of block {block_b}")
    body = partial(_rnl_kernel_body, t_max=t_max, k_clip=k_clip)
    return pl.pallas_call(
        body,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((c, n), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), spike_times.dtype),
        interpret=True,
    )(spike_times, weights, theta)
