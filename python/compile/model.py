"""L2: the TNN column model — forward pass + STDP update in JAX.

Composes the L1 Pallas kernels (:mod:`.kernels.rnl_column`,
:mod:`.kernels.unary_topk`) into the functions the Rust coordinator
executes through PJRT:

* :func:`column_forward` — batched RNL first-crossing spike times with
  the Catwalk k-clip, plus the 1-WTA winner mask.
* :func:`train_step` — forward + Smith-style STDP weight update
  (winner-gated, expected-value form); this is the online-learning step
  the end-to-end clustering example drives for a few hundred steps.
* :func:`topk_eval` — the standalone unary top-k network over waveforms,
  exported for runtime conformance benches against the gate-level
  simulator.

Everything here is lowered ONCE by ``compile/aot.py`` to HLO text under
``artifacts/``; Python never runs on the request path.
"""

from __future__ import annotations

from functools import partial

import jax.numpy as jnp

from .kernels.rnl_column import rnl_column
from .kernels.unary_topk import unary_topk

T_MAX = 16
W_MAX = 7.0


def wta(out_times: jnp.ndarray, t_max: int = T_MAX) -> jnp.ndarray:
    """1-WTA one-hot mask of the earliest-spiking column per batch row
    (ties -> lowest index; all-zero when nothing spiked)."""
    winner = jnp.argmin(out_times, axis=-1)
    any_spike = jnp.min(out_times, axis=-1) < t_max
    onehot = (
        jnp.zeros_like(out_times)
        .at[jnp.arange(out_times.shape[0]), winner]
        .set(1.0)
    )
    return onehot * any_spike[:, None].astype(out_times.dtype)


def column_forward(
    spike_times: jnp.ndarray,
    weights: jnp.ndarray,
    theta: jnp.ndarray,
    *,
    k_clip: int | None = 2,
    t_max: int = T_MAX,
):
    """Forward pass: (out_times [B,C], winner_mask [B,C])."""
    out_times = rnl_column(spike_times, weights, theta, t_max=t_max, k_clip=k_clip)
    return out_times, wta(out_times, t_max)


def stdp_update(
    weights: jnp.ndarray,
    in_times: jnp.ndarray,
    out_times: jnp.ndarray,
    winner_mask: jnp.ndarray,
    *,
    t_max: int = T_MAX,
    w_max: float = W_MAX,
    mu_capture: float = 0.30,
    mu_backoff: float = 0.20,
    mu_search: float = 0.02,
) -> jnp.ndarray:
    """Winner-gated expected-value STDP (see kernels/ref.py:stdp_ref for
    the rule table; this is the jitted production form)."""
    x_spk = (in_times < t_max)[:, None, :]
    y_spk = (out_times < t_max)[:, :, None]
    t_x = in_times[:, None, :]
    t_y = out_times[:, :, None]
    w = weights[None, :, :]

    capture = x_spk & y_spk & (t_x <= t_y)
    backoff = (x_spk & y_spk & (t_x > t_y)) | (~x_spk & y_spk)
    search = x_spk & ~y_spk

    delta = (
        capture.astype(w.dtype) * mu_capture * (w_max - w)
        - backoff.astype(w.dtype) * mu_backoff * w
        + search.astype(w.dtype) * mu_search * (w_max - w)
    )
    no_spike_row = (jnp.min(out_times, axis=-1) >= t_max).astype(w.dtype)[:, None]
    gate = jnp.clip(winner_mask + no_spike_row, 0.0, 1.0)
    batch = jnp.mean(delta * gate[:, :, None], axis=0)
    return jnp.clip(weights + batch, 0.0, w_max)


def train_step(
    weights: jnp.ndarray,
    spike_times: jnp.ndarray,
    theta: jnp.ndarray,
    *,
    k_clip: int | None = 2,
    t_max: int = T_MAX,
):
    """One online-learning step: forward + STDP.

    Returns (new_weights [C,n], out_times [B,C], winner_mask [B,C]).
    """
    out_times, mask = column_forward(
        spike_times, weights, theta, k_clip=k_clip, t_max=t_max
    )
    new_w = stdp_update(weights, spike_times, out_times, mask, t_max=t_max)
    return new_w, out_times, mask


def topk_eval(waves: jnp.ndarray, *, k: int = 2) -> jnp.ndarray:
    """Standalone unary top-k network evaluation (conformance target)."""
    return unary_topk(waves, k)
