"""AOT pipeline: lower the L2 model to HLO *text* artifacts.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emits, per column configuration (n inputs, C columns, batch B):

* ``tnn_forward_n{n}_c{c}_b{b}.hlo.txt``  — column_forward (k_clip = 2)
* ``tnn_train_n{n}_c{c}_b{b}.hlo.txt``    — train_step (fwd + STDP)
* ``topk_eval_n{n}_k2_b{b}.hlo.txt``      — standalone top-k network
* ``manifest.json``                        — shapes/dtypes for the Rust
  runtime (rust/src/runtime reads this to validate literals).

Run via ``make artifacts`` (no-op when inputs are unchanged). Python
never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import T_MAX, column_forward, topk_eval, train_step

# The column configurations the experiments and examples use.
CONFIGS = [
    {"n": 16, "c": 8, "b": 64},
    {"n": 32, "c": 12, "b": 64},
    {"n": 64, "c": 16, "b": 64},
]
K = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_all(outdir: str) -> dict:
    manifest = {"t_max": T_MAX, "k": K, "entries": []}

    for cfg in CONFIGS:
        n, c, b = cfg["n"], cfg["c"], cfg["b"]

        fwd = jax.jit(partial(column_forward, k_clip=K))
        path = f"tnn_forward_n{n}_c{c}_b{b}.hlo.txt"
        text = to_hlo_text(fwd.lower(f32(b, n), f32(c, n), f32(1, 1)))
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": f"tnn_forward_n{n}_c{c}_b{b}",
                "file": path,
                "inputs": [[b, n], [c, n], [1, 1]],
                "outputs": [[b, c], [b, c]],
                "kind": "forward",
                "n": n,
                "c": c,
                "b": b,
            }
        )

        tr = jax.jit(partial(train_step, k_clip=K))
        path = f"tnn_train_n{n}_c{c}_b{b}.hlo.txt"
        text = to_hlo_text(tr.lower(f32(c, n), f32(b, n), f32(1, 1)))
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": f"tnn_train_n{n}_c{c}_b{b}",
                "file": path,
                "inputs": [[c, n], [b, n], [1, 1]],
                "outputs": [[c, n], [b, c], [b, c]],
                "kind": "train",
                "n": n,
                "c": c,
                "b": b,
            }
        )

        tk = jax.jit(partial(topk_eval, k=K))
        path = f"topk_eval_n{n}_k{K}_b{b}.hlo.txt"
        text = to_hlo_text(tk.lower(f32(b, n, T_MAX)))
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": f"topk_eval_n{n}_k{K}_b{b}",
                "file": path,
                "inputs": [[b, n, T_MAX]],
                "outputs": [[b, K, T_MAX]],
                "kind": "topk",
                "n": n,
                "c": K,
                "b": b,
            }
        )

    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    manifest = lower_all(args.outdir)
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['entries'])} HLO artifacts to {args.outdir}")


if __name__ == "__main__":
    main()
