//! Design-space exploration: sweep all four dendrite designs across
//! n in {16, 32, 64} in parallel on the thread pool, printing synthesis
//! and P&R cost per point plus the derived headline ratios.
//!
//! Run: `cargo run --release --example dse`

use catwalk::coordinator::dse::{paper_grid, sweep};
use catwalk::experiments::activity::StimulusConfig;
use catwalk::neuron::DendriteKind;
use catwalk::report::{ratio, Table};
use std::time::Instant;

fn main() -> catwalk::Result<()> {
    let stim = StimulusConfig {
        windows: 128,
        ..Default::default()
    };
    let t0 = Instant::now();
    let results = sweep(&paper_grid(), &stim, 0)?;
    println!(
        "swept {} design points in {:?} across {} threads",
        results.len(),
        t0.elapsed(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    let mut t = Table::new(
        "DSE: all paper design points",
        &["design", "n", "synth area", "synth uW", "pnr area", "pnr uW", "depth"],
    );
    for r in &results {
        t.row(vec![
            r.point.kind.label().into(),
            r.point.n.to_string(),
            format!("{:.2}", r.synthesis.area_um2),
            format!("{:.2}", r.synthesis.total_uw()),
            format!("{:.2}", r.pnr.area_um2),
            format!("{:.2}", r.pnr.total_uw()),
            r.pnr.logic_depth.to_string(),
        ]);
    }
    print!("{}", t.render());

    // Derived headline ratios per n.
    for n in [16usize, 32, 64] {
        let base = results
            .iter()
            .find(|r| r.point.n == n && r.point.kind == DendriteKind::PcCompact)
            .unwrap();
        let cat = results
            .iter()
            .find(|r| r.point.n == n && r.point.kind == DendriteKind::TopkPc)
            .unwrap();
        println!(
            "n={n:>2}: Catwalk vs compact PC -> {} area, {} power",
            ratio(base.pnr.area_um2, cat.pnr.area_um2),
            ratio(base.pnr.total_uw(), cat.pnr.total_uw()),
        );
    }
    Ok(())
}
