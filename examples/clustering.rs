//! E10 — end-to-end validation: online STDP clustering through the full
//! stack (Rust coordinator -> execution backend -> RNL column kernels).
//!
//! Trains a 64-input, 16-neuron TNN column for a few hundred steps on the
//! synthetic clustered time-series workload, logging purity convergence
//! and execution latency. Runs on the native backend out of the box;
//! a build with `--features xla` (against real xla-rs, see DESIGN.md §3)
//! plus `make artifacts` and `CATWALK_BACKEND=xla` switches to PJRT.
//!
//! Run: `cargo run --release --example clustering`

use catwalk::coordinator::TnnHandle;
use catwalk::tnn::workload::ClusteredSeries;
use catwalk::tnn::{purity, GrfEncoder, WorkloadConfig};
use std::time::Instant;

fn main() -> catwalk::Result<()> {
    let n = 64;
    let steps = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(1500);
    // threshold scales with expected simultaneous response mass (see E8)
    let theta = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12.0);
    let handle = TnnHandle::open("artifacts", n, theta, 42)?;
    println!(
        "{} column up: n={} c={} batch={} t_max={}",
        handle.backend, handle.n, handle.c, handle.b, handle.t_max
    );

    let fields = 8;
    let mut enc = GrfEncoder::new(n / fields, fields, 0.0, 1.0);
    // keep the volley in the sparse regime the paper's k = 2 assumes
    // (E8: with ~10% line activity the top-2 clip almost never engages)
    enc.cutoff = 0.60;
    let mut series = ClusteredSeries::new(WorkloadConfig {
        dims: n / fields,
        seed: 42,
        ..Default::default()
    });

    let t0 = Instant::now();
    let mut final_purity = 0.0;
    for step in 0..steps {
        let samples = series.next_batch(handle.b);
        let volleys: Vec<Vec<f32>> = samples.iter().map(|(_, s)| enc.encode(s)).collect();
        let results = handle.learn(volleys)?;
        if step % 25 == 0 || step + 1 == steps {
            let assignments: Vec<(usize, Option<usize>)> = samples
                .iter()
                .zip(&results)
                .map(|((label, _), r)| (*label, r.winner))
                .collect();
            let p = purity(&assignments, 4, handle.c);
            let fired = results.iter().filter(|r| r.winner.is_some()).count();
            final_purity = p;
            println!(
                "step {step:>4}  purity {:.3}  firing {:.2}  throughput {:.0} volleys/s",
                p,
                fired as f64 / handle.b as f64,
                ((step + 1) * handle.b) as f64 / t0.elapsed().as_secs_f64()
            );
        }
    }
    println!("\nbackend metrics:\n{}", handle.metrics.render());
    println!("final purity after {steps} steps: {final_purity:.3}");
    assert!(
        final_purity > 0.6,
        "clustering should converge (purity {final_purity})"
    );
    println!("OK: full L3->L2->L1 stack converges on the clustering workload");
    Ok(())
}
