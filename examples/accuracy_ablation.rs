//! E9 — the accuracy study the paper defers ("More experimental work is
//! needed to validate this"): clustering purity vs dendrite clip k, plus
//! the E8 sparsity/overlap statistics that justify k = 2.
//!
//! Run: `cargo run --release --example accuracy_ablation`

use catwalk::experiments::ablation::ablate_k;
use catwalk::experiments::sparsity::{sparsity_study, workload_activity};

fn main() -> catwalk::Result<()> {
    println!("== E8: how often would a top-k dendrite clip? ==");
    print!("{}", sparsity_study(5000, 1)?.render());
    println!(
        "GRF workload line activity: {:.1}% of lines spike per volley (paper cites 0.1-10%)\n",
        workload_activity(500, 5) * 100.0
    );

    println!("== E9: does the k-clip hurt clustering accuracy? ==");
    let t = ablate_k(800, 400, 11)?;
    print!("{}", t.render());
    println!(
        "Reading: k = 2 purity should sit within noise of the unclipped dendrite\n\
         while k = 1 clips hard — the experimental backing for the paper's k = 2."
    );
    Ok(())
}
