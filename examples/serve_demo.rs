//! Serving demo: boots the TCP daemon on an ephemeral port, drives it
//! with concurrent text-protocol clients through the dynamic batcher,
//! re-runs the same load over the framed protocol (32-volley batch
//! frames, which coalesce into whole backend batches), then exercises
//! the multi-model registry — create a second column over the wire,
//! interleave routed traffic, checkpoint and hot-swap it — and prints
//! every set of numbers.
//!
//! Runs on the native backend out of the box; a build with
//! `--features xla` (against real xla-rs, see DESIGN.md §3) plus
//! `make artifacts` and `CATWALK_BACKEND=xla` switches to PJRT.
//!
//! Run: `cargo run --release --example serve_demo`

use catwalk::coordinator::pool::par_map;
use catwalk::coordinator::BatcherConfig;
use catwalk::proto::Request;
use catwalk::registry::{ModelRegistry, ModelSpec, RegistryConfig};
use catwalk::server::{Client, FramedClient, Server};
use catwalk::tnn::workload::ClusteredSeries;
use catwalk::tnn::{GrfEncoder, WorkloadConfig};
use catwalk::SpikeVolley;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn main() -> catwalk::Result<()> {
    let n = 64;
    let ckpt_dir = std::env::temp_dir().join(format!("catwalk-demo-ckpts-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let registry = Arc::new(ModelRegistry::open(
        RegistryConfig {
            ckpt_dir: Some(ckpt_dir.clone()),
            batcher: BatcherConfig::default(),
            ..RegistryConfig::default()
        },
        "default",
        ModelSpec {
            n,
            theta: 6.0,
            seed: 7,
        },
    )?);
    let default_slot = registry.slot(None)?;
    println!("backend: {}", default_slot.backend());
    let metrics = default_slot.metrics().clone();
    drop(default_slot);
    let server = Arc::new(Server::with_registry(registry));
    let stop = server.stop_handle();
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |p| {
                    let _ = port_tx.send(p);
                })
                .unwrap()
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    println!("daemon up on {addr}");

    let conns = 8;
    let per_conn = 64;
    let t0 = Instant::now();
    let lats = par_map(conns, (0..conns).collect::<Vec<_>>(), |ci| {
        let mut client = Client::connect(&addr).expect("connect");
        let enc = GrfEncoder::new(n / 8, 8, 0.0, 1.0);
        let mut series = ClusteredSeries::new(WorkloadConfig {
            dims: n / 8,
            seed: ci as u64,
            ..Default::default()
        });
        let mut out = Vec::new();
        for _ in 0..per_conn {
            let (_, s) = series.next_sample();
            let t = Instant::now();
            client.infer(&enc.encode(&s)).expect("infer");
            out.push(t.elapsed());
        }
        let _ = client.quit();
        out
    });
    let wall = t0.elapsed();
    let mut all: Vec<_> = lats.into_iter().flatten().collect();
    all.sort();
    let total = all.len();
    println!(
        "text protocol: {total} requests / {conns} connections in {wall:?} -> {:.0} req/s",
        total as f64 / wall.as_secs_f64()
    );
    println!(
        "client latency p50 {:?} p95 {:?} max {:?}",
        all[total / 2],
        all[total * 95 / 100],
        all[total - 1]
    );

    // the same load over the v2 framed protocol, one 32-volley batch
    // frame per window: a multi-volley request enters the batcher as a
    // whole (DynamicBatcher::submit_many), so each window coalesces
    // into full backend batches instead of paying the flush timer one
    // volley at a time
    let window = 32;
    let t0 = Instant::now();
    let counts = par_map(conns, (0..conns).collect::<Vec<_>>(), |ci| {
        let mut client = FramedClient::connect(&addr).expect("connect");
        let enc = GrfEncoder::new(n / 8, 8, 0.0, 1.0);
        let mut series = ClusteredSeries::new(WorkloadConfig {
            dims: n / 8,
            seed: ci as u64,
            ..Default::default()
        });
        let mut done = 0usize;
        while done < per_conn {
            let take = window.min(per_conn - done);
            let volleys: Vec<SpikeVolley> = (0..take)
                .map(|_| {
                    let (_, s) = series.next_sample();
                    SpikeVolley::dense(enc.encode(&s))
                })
                .collect();
            let resp = client
                .call(Request::infer(volleys))
                .expect("batch infer");
            done += resp.results().expect("results").len();
        }
        let _ = client.quit();
        done
    });
    let wall_framed = t0.elapsed();
    let total_framed: usize = counts.iter().sum();
    println!(
        "\nv2 framed ({window}-volley batch frames): {total_framed} requests in {wall_framed:?} \
         -> {:.0} req/s ({:.2}x vs text)",
        total_framed as f64 / wall_framed.as_secs_f64(),
        wall.as_secs_f64() / wall_framed.as_secs_f64()
    );

    // ---- multi-model registry over the wire: create a second (small,
    // hotter-threshold) column, interleave routed traffic, checkpoint
    // it, drift it with learning, hot-swap the checkpoint back
    println!("\nregistry demo:");
    let mut admin = FramedClient::connect(&addr)?;
    let info = admin.create_model("edge", 16, 4.0, 3)?;
    println!(
        "  created model {} (n={} c={} theta={})",
        info.name, info.n, info.c, info.theta
    );
    let edge_volley = vec![0.0f32; 16];
    let wide_volley = vec![0.0f32; n];
    let t0 = Instant::now();
    let rounds = 128;
    for _ in 0..rounds {
        admin.infer(&wide_volley)?; // default model, unrouted
        admin.infer_model("edge", &edge_volley)?; // routed by name
        admin.learn_model("edge", &edge_volley)?;
    }
    println!(
        "  interleaved {} requests across 2 models in {:?}",
        rounds * 3,
        t0.elapsed()
    );
    let receipt = admin.save_model("edge")?;
    println!("  {receipt}");
    let before = admin.infer_model("edge", &edge_volley)?;
    for _ in 0..16 {
        admin.learn_model("edge", &edge_volley)?; // drift the weights
    }
    admin.load_model("edge")?;
    let after = admin.infer_model("edge", &edge_volley)?;
    println!(
        "  hot-swap restored checkpointed weights: replies identical = {}",
        before == after
    );
    for m in admin.models()? {
        println!(
            "  model {:10} n={:3} c={:3} theta={:5} seed={}{}",
            m.name,
            m.n,
            m.c,
            m.theta,
            m.seed,
            if m.default { "  (default)" } else { "" }
        );
    }
    let stats = admin.stats()?;
    println!(
        "  merged stats: requests={} (default={}, edge={})",
        stats.counter("requests"),
        stats.counter("model.default.requests"),
        stats.counter("model.edge.requests")
    );
    admin.unload_model("edge")?;
    let _ = admin.quit();

    println!("\nserver metrics:\n{}", metrics.render());

    stop.store(true, Ordering::Release);
    srv.join().unwrap();
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    println!("daemon stopped cleanly");
    Ok(())
}
