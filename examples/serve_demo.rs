//! Serving demo: boots the TCP daemon on an ephemeral port, drives it
//! with concurrent clients through the dynamic batcher, prints the
//! latency/throughput numbers, then shuts down cleanly.
//!
//! Runs on the native backend out of the box; a build with
//! `--features xla` (against real xla-rs, see DESIGN.md §3) plus
//! `make artifacts` and `CATWALK_BACKEND=xla` switches to PJRT.
//!
//! Run: `cargo run --release --example serve_demo`

use catwalk::coordinator::pool::par_map;
use catwalk::coordinator::{BatcherConfig, TnnHandle};
use catwalk::server::{Client, Server};
use catwalk::tnn::workload::ClusteredSeries;
use catwalk::tnn::{GrfEncoder, WorkloadConfig};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn main() -> catwalk::Result<()> {
    let n = 64;
    let handle = TnnHandle::open("artifacts", n, 6.0, 7)?;
    println!("backend: {}", handle.backend);
    let metrics = handle.metrics.clone();
    let server = Arc::new(Server::new(handle, BatcherConfig::default()));
    let stop = server.stop_handle();
    let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
    let srv = {
        let server = server.clone();
        std::thread::spawn(move || {
            server
                .serve("127.0.0.1:0", move |p| {
                    let _ = port_tx.send(p);
                })
                .unwrap()
        })
    };
    let addr = format!("127.0.0.1:{}", port_rx.recv().unwrap());
    println!("daemon up on {addr}");

    let conns = 8;
    let per_conn = 64;
    let t0 = Instant::now();
    let lats = par_map(conns, (0..conns).collect::<Vec<_>>(), |ci| {
        let mut client = Client::connect(&addr).expect("connect");
        let enc = GrfEncoder::new(n / 8, 8, 0.0, 1.0);
        let mut series = ClusteredSeries::new(WorkloadConfig {
            dims: n / 8,
            seed: ci as u64,
            ..Default::default()
        });
        let mut out = Vec::new();
        for _ in 0..per_conn {
            let (_, s) = series.next_sample();
            let t = Instant::now();
            client.infer(&enc.encode(&s)).expect("infer");
            out.push(t.elapsed());
        }
        let _ = client.quit();
        out
    });
    let wall = t0.elapsed();
    let mut all: Vec<_> = lats.into_iter().flatten().collect();
    all.sort();
    let total = all.len();
    println!(
        "{total} requests / {conns} connections in {wall:?} -> {:.0} req/s",
        total as f64 / wall.as_secs_f64()
    );
    println!(
        "client latency p50 {:?} p95 {:?} max {:?}",
        all[total / 2],
        all[total * 95 / 100],
        all[total - 1]
    );
    println!("\nserver metrics:\n{}", metrics.render());

    stop.store(true, Ordering::Release);
    srv.join().unwrap();
    println!("daemon stopped cleanly");
    Ok(())
}
