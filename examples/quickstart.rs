//! Quickstart: build a Catwalk neuron, inspect its cost, push a spike
//! volley through the gate-level netlist, and compare it with the
//! baseline SRM0-RNL neuron.
//!
//! Run: `cargo run --release --example quickstart`

use catwalk::experiments::activity::{measure_neuron, StimulusConfig};
use catwalk::neuron::stimulus::GAMMA_LEN;
use catwalk::neuron::{DendriteKind, NeuronConfig, NeuronDesign};
use catwalk::power::Estimator;
use catwalk::report::ratio;
use catwalk::sim::Simulator;
use catwalk::topk::TopkSelector;

fn main() -> catwalk::Result<()> {
    // 1. The paper's headline device: 64-input neuron, top-2 dendrite.
    let cfg = NeuronConfig {
        n_inputs: 64,
        k: 2,
        ..Default::default()
    };
    let catwalk = NeuronDesign::build(DendriteKind::TopkPc, &cfg)?;
    let baseline = NeuronDesign::build(DendriteKind::PcCompact, &cfg)?;

    let sel = TopkSelector::catwalk(64, 2)?;
    let st = sel.stats();
    println!("Catwalk top-2 selector for n=64:");
    println!(
        "  source network {} CS units -> {} mandatory, {} half (Algorithm 1)",
        st.total, st.mandatory, st.half
    );
    println!(
        "  selector+1-FA-PC dendrite: {} gates vs the baseline 63-FA PC: {} gate-eq",
        sel.gate_count() + 5,
        63 * 5
    );
    println!(
        "  (whole-neuron gate-eq: catwalk {}, baseline {})\n",
        catwalk.netlist.stats().gate_equivalents(),
        baseline.netlist.stats().gate_equivalents()
    );

    // 2. Simulate a volley through the real netlist: three early spikes.
    let mut sim = Simulator::new(&catwalk.netlist);
    let threshold = 6;
    sim.step(&catwalk.pack_inputs(&vec![false; 64], threshold, true)); // reset
    println!("volley: lines 3, 17, 40 pulse from t=1/2/3 (widths 5/4/3), threshold {threshold}");
    let mut fired_at = None;
    for t in 0..GAMMA_LEN {
        let mut pulses = vec![false; 64];
        pulses[3] = (1..6).contains(&t);
        pulses[17] = (2..6).contains(&t);
        pulses[40] = (3..6).contains(&t);
        let out = sim.step(&catwalk.pack_inputs(&pulses, threshold, false));
        if out[0] && fired_at.is_none() {
            fired_at = Some(t);
        }
    }
    println!("axon fired at cycle {:?} (8-cycle output pulse)\n", fired_at);

    // 3. Synthesis + P&R comparison under realistic activity.
    let stim = StimulusConfig {
        windows: 96,
        ..Default::default()
    };
    let est = Estimator::pnr();
    let rc = est.evaluate(&catwalk.netlist, Some(&measure_neuron(&catwalk, &stim)));
    let rb = est.evaluate(&baseline.netlist, Some(&measure_neuron(&baseline, &stim)));
    println!("P&R estimate @ 400 MHz (64-lane activity simulation):");
    println!(
        "  PC compact [7]   : {:>7.2} um^2  {:>7.2} uW",
        rb.area_um2,
        rb.total_uw()
    );
    println!(
        "  Catwalk (top-2)  : {:>7.2} um^2  {:>7.2} uW",
        rc.area_um2,
        rc.total_uw()
    );
    println!(
        "  improvement      : {} area, {} power (paper: 1.39x / 1.86x)",
        ratio(rb.area_um2, rc.area_um2),
        ratio(rb.total_uw(), rc.total_uw())
    );
    Ok(())
}
