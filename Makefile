# Local entry points mirroring .github/workflows/ci.yml — keep the two in
# lockstep so local runs and CI always exercise the same commands.

.PHONY: build test bench bench-json lint fmt check python-test artifacts all clean clean-checkpoints

all: lint build test bench

build:
	cargo build --release

test:
	cargo test -q

# benches must at least compile; `make bench-run` executes them
bench:
	cargo bench --no-run

bench-run:
	cargo bench

# machine-readable perf-trajectory point: sweeps every KernelPlan path
# over the density range and writes BENCH_<pr>.json at the repo root
# (BENCH_JSON_OUT overrides the path, CATWALK_SPARSE_CUTOVER the auto
# cutover)
bench-json:
	cargo bench --bench bench_json

lint:
	cargo fmt --all --check
	cargo clippy --all-targets -- -D warnings
	cargo clippy --all-targets --features xla -- -D warnings

fmt:
	cargo fmt --all

check:
	cargo check --all-targets
	cargo check --all-targets --features xla

python-test:
	python3 -m pytest python/tests -q

# AOT-lower the JAX/Pallas kernels to HLO-text artifacts for the PJRT
# backend (the native backend needs none of this).
artifacts:
	cd python && python3 -m compile.aot --outdir ../artifacts

# Weight checkpoints written by `repro serve --ckpt-dir checkpoints`
# (and its autosave loop) are runtime state, not build outputs — they
# get their own clean target so wiping builds never deletes learned
# weights by accident, and vice versa. The directory holds CWKP weight
# files, and for sharded models (--models ...,shards=K) the CWKS shard
# manifests plus their <name>.shard<i>.<crc>.ckpt siblings — all removed
# together, so a later boot can never resume from a half-wiped shard
# set.
clean-checkpoints:
	rm -rf checkpoints

clean: clean-checkpoints
	cargo clean
	rm -rf artifacts
